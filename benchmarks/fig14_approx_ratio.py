"""Paper Fig 14: ANN approximation ratio vs k (E2LSH on SIFT-like data)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, ann_dataset, query_sigs, timeit
from repro.core import GenieIndex


def run() -> list[Row]:
    pts, _, params, sigs = ann_dataset(m=128)
    idx = GenieIndex.build_lsh(sigs, use_kernel=False)
    qs, qpts = query_sigs(params, pts, np.arange(64) % pts.shape[0], noise=0.3)
    dists = np.linalg.norm(pts[None] - qpts[:, None], axis=-1)
    rows = []
    for k in (1, 10, 50, 100):
        res = idx.search(jnp.asarray(qs), k=k)
        got = np.sort(np.take_along_axis(dists, np.asarray(res.ids), axis=1), axis=1)
        true = np.sort(dists, axis=1)[:, :k]
        ratio = float(np.mean(got / np.maximum(true, 1e-9)))
        rows.append(Row(f"fig14.approx_ratio.k{k}", 0.0, f"ratio={ratio:.3f}"))
    return rows
