"""Paper Fig 8: minimum required LSH functions m vs similarity s."""
from benchmarks.common import Row, timeit_host
from repro.core.lsh import tau_ann


def run() -> list[Row]:
    us = timeit_host(lambda: tau_ann.min_m_for_similarity(0.5, 0.06, 0.06, m_max=1024), iters=1)
    ss, ms = tau_ann.fig8_curve(0.06, 0.06, s_grid=21, m_max=1024)
    peak_m, peak_s = int(ms.max()), float(ss[ms.argmax()])
    return [
        Row("fig8.min_m@s=0.5", us, f"m={tau_ann.min_m_for_similarity(0.5, 0.06, 0.06)}"),
        Row("fig8.max_over_s", 0.0, f"m={peak_m}@s={peak_s:.2f};paper=237@0.5"),
        Row("fig8.theorem41_bound", 0.0, f"m={tau_ann.m_theorem41(0.06, 0.06)}"),
    ]
