"""Paper Fig 13 / Table IV: effectiveness of c-PQ -- selection time and
per-query memory vs SPQ (bucket k-selection) and full sort."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, ann_dataset, timeit
from repro.core import cpq, spq
from repro.core.types import SearchParams


def run() -> list[Row]:
    _, _, _, sigs = ann_dataset()
    n, m = sigs.shape
    rng = np.random.default_rng(9)
    rows = []
    for nq in (64, 256):
        counts = jnp.asarray(rng.binomial(m, 0.15, size=(nq, n)).astype(np.int32))
        p = SearchParams(k=100, max_count=m)
        f_cpq = jax.jit(lambda c: cpq.cpq_select(c, p).ids)
        f_spq = jax.jit(lambda c: spq.spq_select(c, p).ids)
        f_sort = jax.jit(lambda c: cpq.sort_select(c, p).ids)
        t_cpq = timeit(f_cpq, counts)
        t_spq = timeit(f_spq, counts)
        t_sort = timeit(f_sort, counts)
        rows.append(Row(f"fig13.cpq.q{nq}", t_cpq, f"vs_sort={t_sort/t_cpq:.2f}x"))
        rows.append(Row(f"fig13.spq.q{nq}", t_spq, f"vs_sort={t_sort/t_spq:.2f}x"))
        rows.append(Row(f"fig13.sort.q{nq}", t_sort, ""))
    # Table IV: memory per query.  c-PQ: int8 counts (bounded domain) + Gate
    # histogram + cap buffer.  SPQ/sort: fp32-copy working sets over all N.
    p = SearchParams(k=100, max_count=m)
    cpq_bytes = n * 1 + (m + 1) * 4 + p.cap() * 8
    spq_bytes = n * 4 * 2  # value copy + bucket ids per iteration
    rows.append(Row("table4.mem_per_query.cpq", 0.0, f"bytes={cpq_bytes}"))
    rows.append(Row("table4.mem_per_query.spq", 0.0,
                    f"bytes={spq_bytes};ratio={spq_bytes/cpq_bytes:.1f}x"))
    return rows
