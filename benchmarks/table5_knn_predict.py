"""Paper Table V: 1NN label prediction via RBH (Laplacian-kernel) ANN --
precision / recall / F1 / accuracy."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core import GenieIndex
from repro.core.lsh import rbh
from repro.data.pipeline import synthetic_points


def run() -> list[Row]:
    d, m = 32, 128
    pts, labels = synthetic_points(8_000, d, n_clusters=26, seed=13)
    sigma = rbh.median_heuristic_sigma(jnp.asarray(pts), jax.random.PRNGKey(0))
    params = rbh.make(jax.random.PRNGKey(1), d=d, m=m, sigma=sigma, n_buckets=8192)
    train, test = pts[1000:], pts[:1000]
    ltrain, ltest = labels[1000:], labels[:1000]
    idx = GenieIndex.build_lsh(rbh.hash_points(params, jnp.asarray(train)),
                               max_count=m, use_kernel=False)
    tsig = rbh.hash_points(params, jnp.asarray(test))
    us = timeit(lambda: idx.search(tsig, k=1).ids)
    pred = ltrain[np.asarray(idx.search(tsig, k=1).ids)[:, 0]]
    acc = float(np.mean(pred == ltest))
    # macro precision/recall/F1
    ps, rs = [], []
    for c in np.unique(ltest):
        tp = np.sum((pred == c) & (ltest == c))
        ps.append(tp / max(np.sum(pred == c), 1))
        rs.append(tp / max(np.sum(ltest == c), 1))
    p, r = float(np.mean(ps)), float(np.mean(rs))
    f1 = 2 * p * r / max(p + r, 1e-9)
    return [Row("table5.rbh_1nn", us,
                f"precision={p:.3f};recall={r:.3f};f1={f1:.3f};accuracy={acc:.3f}")]
