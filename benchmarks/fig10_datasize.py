"""Paper Fig 10: running time vs data size (512 queries in the paper; 128
here)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, ann_dataset, query_sigs, timeit
from repro.core import GenieIndex


def run() -> list[Row]:
    rows = []
    for n in (5_000, 10_000, 20_000):
        pts, _, params, sigs = ann_dataset(n=n)
        idx = GenieIndex.build_lsh(sigs, use_kernel=False)
        qs, _ = query_sigs(params, pts, np.arange(128) % n)
        us = timeit(lambda q=jnp.asarray(qs), i=idx: i.search(q, k=100).ids)
        rows.append(Row(f"fig10.genie.n{n}", us, f"us_per_Mobj={us/n*1e6:.0f}"))
    return rows
