"""Paper Fig 9/11: total running time for multiple queries -- GENIE (c-PQ)
vs GEN-SPQ vs sort vs CPU-Idx (numpy postings scan)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, ann_dataset, query_sigs, timeit, timeit_host
from repro.core import GenieIndex, TopKMethod
from repro.core.postings import PostingsIndex


def run() -> list[Row]:
    pts, _, params, sigs = ann_dataset()
    n, m = sigs.shape
    idx = GenieIndex.build_lsh(sigs, use_kernel=False)
    rows = []
    for nq in (32, 128, 512):
        qs, _ = query_sigs(params, pts, np.arange(nq) % pts.shape[0])
        qs_j = jnp.asarray(qs)
        for method in (TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT):
            us = timeit(lambda q=qs_j, mth=method: idx.search(q, k=100, method=mth).ids)
            rows.append(Row(f"fig9.genie_{method.value}.q{nq}", us,
                            f"N={n};m={m};per_query_us={us/nq:.1f}"))
        # CPU-Idx baseline (paper competitor): postings scan + numpy partial sort
        if nq <= 128:
            keywords = sigs + (np.arange(m, dtype=np.int32) * 67)[None]
            pidx = PostingsIndex.build(keywords, n_keywords=m * 67)
            qkw = qs + (np.arange(m, dtype=np.int32) * 67)[None]

            def cpu_idx(q=qkw):
                counts = pidx.scan_counts_numpy(q)
                return np.argpartition(-counts, 100, axis=1)[:, :100]

            us = timeit_host(cpu_idx, iters=1)
            rows.append(Row(f"fig9.cpu_idx.q{nq}", us, f"per_query_us={us/nq:.1f}"))
    # Fig 11 analogue: one big batch vs split batches
    qs, _ = query_sigs(params, pts, np.arange(1024) % pts.shape[0])
    qs_j = jnp.asarray(qs)
    us_big = timeit(lambda: idx.search(qs_j, k=100).ids)
    us_split = timeit(lambda: [idx.search(qs_j[i * 256:(i + 1) * 256], k=100).ids for i in range(4)])
    rows.append(Row("fig11.batch1024_single", us_big, ""))
    rows.append(Row("fig11.batch1024_4x256", us_split, f"overhead={us_split/us_big:.2f}x"))
    return rows
