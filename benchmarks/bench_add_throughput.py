"""Add-throughput micro-benchmark: segmented append vs rebuild-on-add.

`RetrievalService.add` used to rebuild the whole GenieIndex on every call,
so appending B equal batches cost O(N^2/B) device work.  The segmented path
(core/segments.py) seals each batch into an immutable segment: per-add cost
must stay flat in corpus size.  This benchmark appends B equal batches both
ways, times every add, and emits a machine-readable line

    BENCH {"name": "add_throughput", ...}

consumed by tools/ci.sh.  The flatness check is a loose 4x bound on
(last-half median / first-half median) of segmented per-add time -- the
rebuild path's same ratio is reported alongside for contrast (it grows
with B).
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import Row


def _per_add_seconds(add_fn, batches) -> list[float]:
    import jax

    ts = []
    for batch in batches:
        t0 = time.perf_counter()
        out = add_fn(batch)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return ts


def run(n_batches: int = 12, batch: int = 2048, m: int = 64, d: int = 16,
        warmup: int = 2) -> list[Row]:
    import jax
    import jax.numpy as jnp

    from repro.core import GenieIndex, SegmentedIndex
    from repro.core import lsh as lsh_lib
    from repro.core.types import Engine

    rng = np.random.default_rng(0)
    scheme = lsh_lib.get_scheme("e2lsh")
    params = scheme.make_params(jax.random.PRNGKey(0), d=d, m=m, w=4.0,
                                n_buckets=1024)
    batches = [
        np.asarray(scheme.hash_points(
            params, jnp.asarray(rng.standard_normal((batch, d)).astype(np.float32))))
        for _ in range(warmup + n_batches)
    ]

    # segmented append: O(batch) per call
    seg = SegmentedIndex(engine=Engine.EQ, max_count=m, use_kernel=False)
    seg_ts = _per_add_seconds(lambda b: seg.add(b).data, batches)[warmup:]

    # rebuild-on-add (the old RetrievalService.add): O(corpus) per call
    acc: list[np.ndarray] = []

    def rebuild(b):
        acc.append(b)
        return GenieIndex.build(Engine.EQ, np.concatenate(acc, axis=0),
                                max_count=m, use_kernel=False).data

    rb_ts = _per_add_seconds(rebuild, batches)[warmup:]

    half = len(seg_ts) // 2
    # median per half: robust to a single GC pause / noisy-neighbor stall,
    # which would flake a mean-based CI gate
    ratio = lambda ts: float(np.median(ts[half:]) / max(np.median(ts[:half]), 1e-12))
    report = dict(
        name="add_throughput",
        n_batches=n_batches, batch=batch, m=m,
        corpus_final=int(seg.n_objects),
        segmented_us_per_add=[round(t * 1e6, 1) for t in seg_ts],
        rebuild_us_per_add=[round(t * 1e6, 1) for t in rb_ts],
        segmented_lastfirst_ratio=round(ratio(seg_ts), 3),
        rebuild_lastfirst_ratio=round(ratio(rb_ts), 3),
        flat=bool(ratio(seg_ts) < 4.0),
    )
    print("BENCH " + json.dumps(report), flush=True)
    _LAST_REPORT.update(report)
    return [
        Row("add_throughput.segmented_mean", float(np.mean(seg_ts)) * 1e6,
            f"ratio={report['segmented_lastfirst_ratio']}"),
        Row("add_throughput.rebuild_mean", float(np.mean(rb_ts)) * 1e6,
            f"ratio={report['rebuild_lastfirst_ratio']}"),
    ]


_LAST_REPORT: dict = {}


def main() -> None:
    for r in run():
        print(r.csv())
    # acceptance gate: per-add cost flat in corpus size (O(batch), not O(N))
    if not _LAST_REPORT.get("flat"):
        raise SystemExit(
            f"add throughput NOT flat: segmented last/first ratio "
            f"{_LAST_REPORT.get('segmented_lastfirst_ratio')}"
        )


if __name__ == "__main__":
    main()
