"""Coarse-routing micro-benchmark: recall@k and segments-scanned ratio vs
the full scan on a clustered corpus (core/routing.py).

The corpus is the IVF-friendly regime the router is built for: each sealed
segment is one cluster of sign-correlated vectors (COSINE engine), and query
traffic is skewed onto a few clusters -- the serving pattern where a corpus
scan is pure waste.  The benchmark drives `SegmentedIndex.search` in all
three routing modes and reports

    BENCH {"name": "routing", ...}

with ROUTED's recall@k against the full scan, the fraction of segments the
routed batch actually scanned (the union over the query batch -- the host
loop runs the whole batch against every scanned part), ROUTED_VERIFIED's
bit-for-bit parity, and p50 wall-times.  Gates (tools/ci.sh):

  * ROUTED_VERIFIED == full scan exactly (ids, counts, thresholds);
  * ROUTED scans < 50% of the segments at recall@k >= 0.95.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import Row


def _recall(routed_ids: np.ndarray, full_ids: np.ndarray) -> float:
    hits = sum(
        len(set(r[r >= 0]) & set(f[f >= 0])) / max(len(set(f[f >= 0])), 1)
        for r, f in zip(routed_ids, full_ids)
    )
    return hits / len(full_ids)


def _p50_us(fn, repeats: int) -> float:
    import jax

    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = fn()
        jax.block_until_ready((res.ids, res.counts))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def run(n_clusters: int = 12, per_cluster: int = 800, d: int = 64,
        q_batch: int = 32, query_clusters: int = 4, k: int = 10,
        nprobe: int = 1, noise: float = 0.1, repeats: int = 9) -> list[Row]:
    from repro.core import Engine, SegmentedIndex
    from repro.core import engines as engines_lib

    rng = np.random.default_rng(5)
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32)
    # one sealed segment per cluster: the seal-time summaries are the
    # router's centroids/bounds, so segment boundaries ARE the coarse cells
    seg = SegmentedIndex(Engine.COSINE, use_kernel=False)
    for c in range(n_clusters):
        pts = centers[c][None, :] + noise * rng.standard_normal(
            (per_cluster, d)).astype(np.float32)
        seg.add(pts)
    # skewed traffic: queries drawn from a few clusters only -- the regime
    # where batch-union routing genuinely skips most of the corpus
    qc = rng.integers(0, query_clusters, q_batch)
    q = (centers[qc] + noise * rng.standard_normal(
        (q_batch, d)).astype(np.float32))

    full = seg.search(q, k)
    routed = seg.search(q, k, routing="routed", nprobe=nprobe)
    verified = seg.search(q, k, routing="routed_verified", nprobe=nprobe)

    parity = (np.array_equal(np.asarray(full.ids), np.asarray(verified.ids))
              and np.array_equal(np.asarray(full.counts),
                                 np.asarray(verified.counts))
              and np.array_equal(np.asarray(full.threshold),
                                 np.asarray(verified.threshold)))
    recall = _recall(np.asarray(routed.ids), np.asarray(full.ids))
    model = engines_lib.get(Engine.COSINE)
    mask, _ = seg.router().select(model.prepare_queries(q), nprobe)
    scanned_ratio = float(mask.sum()) / n_clusters

    p50_full = _p50_us(lambda: seg.search(q, k), repeats)
    p50_routed = _p50_us(
        lambda: seg.search(q, k, routing="routed", nprobe=nprobe), repeats)

    report = dict(
        name="routing",
        engine="cosine", n_objects=n_clusters * per_cluster,
        n_segments=n_clusters, k=k, nprobe=nprobe, q_batch=q_batch,
        query_clusters=query_clusters,
        recall_at_k=round(recall, 4),
        segments_scanned=int(mask.sum()),
        segments_scanned_ratio=round(scanned_ratio, 4),
        verified_parity=bool(parity),
        p50_full_us=round(p50_full, 1),
        p50_routed_us=round(p50_routed, 1),
        speedup_routed=round(p50_full / max(p50_routed, 1e-9), 2),
    )
    print("BENCH " + json.dumps(report), flush=True)
    _LAST_REPORT.update(report)
    return [
        Row("routing.full_scan_p50", p50_full,
            f"segments={n_clusters}"),
        Row("routing.routed_p50", p50_routed,
            f"scanned={report['segments_scanned']}/{n_clusters}"
            f";recall={report['recall_at_k']}"),
    ]


_LAST_REPORT: dict = {}


def main() -> None:
    for r in run():
        print(r.csv())
    if not _LAST_REPORT.get("verified_parity"):
        raise SystemExit("ROUTED_VERIFIED != full scan: parity gate failed")
    if _LAST_REPORT.get("recall_at_k", 0.0) < 0.95:
        raise SystemExit(
            f"ROUTED recall@k {_LAST_REPORT.get('recall_at_k')} < 0.95"
        )
    if _LAST_REPORT.get("segments_scanned_ratio", 1.0) >= 0.5:
        raise SystemExit(
            f"ROUTED scanned {_LAST_REPORT.get('segments_scanned_ratio')} "
            f"of segments (>= 0.5): routing is not sub-linear"
        )


if __name__ == "__main__":
    main()
