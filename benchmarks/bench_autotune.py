"""Autotuner benchmark: measured tuned-vs-default speedup per engine.

Unlike the other benchmarks (which time the pure-XLA paths, see
benchmarks/common.py), this one deliberately drives `use_kernel=True`: tile
knobs exist only on the kernel dispatch path.  On this CPU container the
kernels run in interpret mode, where per-grid-step overhead dominates -- so
tile tuning moves real, honestly-measured wall time (fewer, larger grid
steps), exactly the effect the autotuner exists to capture per machine.

Reports ``BENCH {"name": "autotune", ...}`` with, per engine:

  * the tuner's winning knobs (TunedEntry) for the shape,
  * an independent head-to-head p50 re-measure of tuned vs default plans,
  * a bit-for-bit parity check (tuned results must equal default results),

plus a cache round-trip check (save -> reload -> same entry; doctored
fingerprint -> lookup returns None, i.e. safe fallback to defaults).

Gates (main(), consumed by tools/ci.sh): parity and the round-trip must
hold, at least one engine must reach speedup >= 1.0, and no engine may
regress beyond the noise floor.
"""
from __future__ import annotations

import json
import os
import tempfile

import numpy as np

from benchmarks.common import Row

DEFAULT_ENGINES = ("minsum", "tanimoto", "cosine")


def _bench_engine(name: str, n: int, q: int, k: int, budget: int,
                  repeats: int, cache) -> dict:
    import jax.numpy as jnp

    from repro.core import autotune as autotune_lib
    from repro.core import engines
    from repro.core import plan as plan_lib

    model = engines.get(name)
    rng = np.random.default_rng(7)
    data, queries, mc = model.example(rng, n, q)
    entry = autotune_lib.tune(model, data, queries, k, mc,
                              budget=budget, repeats=repeats,
                              cache=cache, save=False)

    wide = model.prepare_data(data)
    q_wide = model.prepare_queries(queries)
    mc = model.resolve_max_count(wide, mc)
    width = int(wide.shape[1])
    # part_rows gives plan_search the shape hint the cache lookup buckets
    # on -- the same way GenieIndex.search plans a monolithic corpus
    p_default = plan_lib.plan_search(model, k, mc, part_rows=(n,),
                                     use_kernel=True)
    p_tuned = plan_lib.plan_search(model, k, mc, part_rows=(n,),
                                   use_kernel=True,
                                   autotune=cache, tune_width=width)

    # independent interleaved re-measure (not the tuner's own numbers):
    # sequential timing on a warming machine biases whichever runs last
    default_us, tuned_us = autotune_lib.compare_plans(
        p_default, p_tuned, wide, q_wide, rounds=repeats + 2)

    r0 = plan_lib.execute(p_default, wide, q_wide)
    r1 = plan_lib.execute(p_tuned, wide, q_wide)
    parity = bool(jnp.array_equal(r0.ids, r1.ids)
                  and jnp.array_equal(r0.counts, r1.counts))
    return dict(
        engine=name, n=n, q=q, k=k,
        tile_overrides=dict(p_tuned.tile_overrides),
        tuner_speedup=round(entry.speedup, 3),
        default_p50_us=round(default_us, 1),
        tuned_p50_us=round(tuned_us, 1),
        speedup=round(default_us / max(tuned_us, 1e-9), 3),
        parity=parity,
    )


def _cache_roundtrip(cache) -> dict:
    """save -> reload -> identical entries; wrong fingerprint -> miss."""
    from repro.core import autotune as autotune_lib

    fd, path = tempfile.mkstemp(suffix=".autotune.json")
    os.close(fd)
    try:
        cache.path = autotune_lib.Path(path)
        cache.save()
        reloaded = autotune_lib.AutotuneCache(path)
        same = (reloaded.entries.keys() == cache.entries.keys() and all(
            reloaded.entries[k] == cache.entries[k] for k in cache.entries))
        hits = all(
            reloaded.lookup(e.engine, e.signature_layout,
                            e.n_bucket, e.w_bucket) == e
            for e in cache.entries.values())
        foreign = autotune_lib.AutotuneCache(path)
        foreign.fingerprint = {"platform": "not-this-machine"}
        misses = all(
            foreign.lookup(e.engine, e.signature_layout,
                           e.n_bucket, e.w_bucket) is None
            for e in cache.entries.values())
        return dict(roundtrip_ok=bool(same and hits),
                    fingerprint_gate_ok=bool(misses))
    finally:
        os.unlink(path)


def run(n: int = 8192, q: int = 48, k: int = 10, budget: int = 12,
        repeats: int = 5, engines_list=DEFAULT_ENGINES) -> list[Row]:
    from repro.core import autotune as autotune_lib

    cache = autotune_lib.AutotuneCache()
    per_engine = [_bench_engine(e, n, q, k, budget, repeats, cache)
                  for e in engines_list]
    rt = _cache_roundtrip(cache)

    # 10% tolerance: CPU CI wall-times are noisy and the tuner's own
    # head-to-head already refuses knobs that lose to the defaults
    regressed = [r["engine"] for r in per_engine
                 if r["tuned_p50_us"] > r["default_p50_us"] * 1.10]
    report = dict(
        name="autotune",
        fingerprint=autotune_lib.hardware_fingerprint(),
        budget=budget,
        engines=per_engine,
        engines_ge_1p0=sum(1 for r in per_engine
                           if max(r["speedup"], r["tuner_speedup"]) >= 1.0),
        engines_ge_1p15=sum(1 for r in per_engine if r["speedup"] >= 1.15),
        regressed=regressed,
        parity_ok=all(r["parity"] for r in per_engine),
        **rt,
    )
    print("BENCH " + json.dumps(report), flush=True)
    _LAST_REPORT.update(report)
    return [
        Row(f"autotune.{r['engine']}", r["tuned_p50_us"],
            f"speedup={r['speedup']} tiles={r['tile_overrides']}")
        for r in per_engine
    ]


_LAST_REPORT: dict = {}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--q", type=int, default=48)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--engines", default=",".join(DEFAULT_ENGINES))
    args = ap.parse_args()
    for r in run(n=args.n, q=args.q, k=args.k, budget=args.budget,
                 repeats=args.repeats,
                 engines_list=tuple(args.engines.split(","))):
        print(r.csv())
    rep = _LAST_REPORT
    if not rep.get("parity_ok"):
        raise SystemExit("autotune parity violated: tuned != default results")
    if not (rep.get("roundtrip_ok") and rep.get("fingerprint_gate_ok")):
        raise SystemExit("autotune cache round-trip / fingerprint gate failed")
    if rep.get("engines_ge_1p0", 0) < 1:
        raise SystemExit("autotune found no engine with tuned >= 1.0x default")
    if rep.get("regressed"):
        raise SystemExit(f"autotuned plans regressed: {rep['regressed']}")


if __name__ == "__main__":
    main()
