"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper-artifact benchmarks),
then the roofline summary tables when dry-run reports exist.

Every ``BENCH {json}`` line a benchmark prints (the machine-readable report
convention, e.g. bench_serve_latency / bench_autotune) is mirrored to
``BENCH_<name>.json`` at the repo root, so the perf trajectory is tracked
across PRs instead of vanishing with the process stdout.
"""
import contextlib
import io
import json
import os
import sys
import time
import traceback

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_BENCH_PREFIX = "BENCH "


def mirror_bench_line(payload: str, root: str = REPO_ROOT) -> str | None:
    """Persist one ``BENCH {json}`` payload as BENCH_<name>.json; returns the
    written path (None for unparseable/nameless payloads -- a report we
    cannot name is not silently written somewhere surprising)."""
    try:
        report = json.loads(payload)
        name = report["name"]
    except (json.JSONDecodeError, TypeError, KeyError):
        return None
    safe = "".join(c if c.isalnum() or c in "-_" else "_" for c in str(name))
    path = os.path.join(root, f"BENCH_{safe}.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


class _BenchTee(io.TextIOBase):
    """stdout passthrough that mirrors BENCH lines to the repo root."""

    def __init__(self, target):
        self.target = target
        self._buf = ""

    def write(self, s: str) -> int:
        self.target.write(s)
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            if line.startswith(_BENCH_PREFIX):
                mirror_bench_line(line[len(_BENCH_PREFIX):])
        return len(s)

    def flush(self) -> None:
        self.target.flush()


def main() -> None:
    from benchmarks import (
        bench_add_throughput,
        bench_autotune,
        bench_frontend,
        bench_routing,
        bench_serve_latency,
        fig8_num_hash,
        fig9_multiquery,
        fig10_datasize,
        fig12_load_balance,
        fig13_cpq,
        fig14_approx_ratio,
        roofline,
        table1_profiling,
        table2_multiload,
        table5_knn_predict,
        table6_sequence,
    )
    from benchmarks.common import emit

    modules = [
        fig8_num_hash, fig9_multiquery, fig10_datasize, fig12_load_balance,
        table1_profiling, table2_multiload, fig13_cpq, fig14_approx_ratio,
        table5_knn_predict, table6_sequence, bench_add_throughput,
        bench_serve_latency, bench_frontend, bench_routing, bench_autotune,
        roofline,
    ]
    print("name,us_per_call,derived")
    failures = 0
    tee = _BenchTee(sys.stdout)
    for mod in modules:
        t0 = time.perf_counter()
        try:
            with contextlib.redirect_stdout(tee):
                emit(mod.run())
        except Exception as e:  # keep the suite running
            failures += 1
            print(f"{mod.__name__}.ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod.__name__} took {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)

    try:
        from benchmarks import roofline

        roofline.print_tables()
    except Exception as e:
        print(f"# roofline summary unavailable: {e}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
