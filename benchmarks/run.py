"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper-artifact benchmarks),
then the roofline summary tables when dry-run reports exist.
"""
import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        bench_add_throughput,
        bench_frontend,
        bench_routing,
        bench_serve_latency,
        fig8_num_hash,
        fig9_multiquery,
        fig10_datasize,
        fig12_load_balance,
        fig13_cpq,
        fig14_approx_ratio,
        roofline,
        table1_profiling,
        table2_multiload,
        table5_knn_predict,
        table6_sequence,
    )
    from benchmarks.common import emit

    modules = [
        fig8_num_hash, fig9_multiquery, fig10_datasize, fig12_load_balance,
        table1_profiling, table2_multiload, fig13_cpq, fig14_approx_ratio,
        table5_knn_predict, table6_sequence, bench_add_throughput,
        bench_serve_latency, bench_frontend, bench_routing, roofline,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        t0 = time.time()
        try:
            emit(mod.run())
        except Exception as e:  # keep the suite running
            failures += 1
            print(f"{mod.__name__}.ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod.__name__} took {time.time()-t0:.1f}s", file=sys.stderr)

    try:
        from benchmarks import roofline

        roofline.print_tables()
    except Exception as e:
        print(f"# roofline summary unavailable: {e}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
