"""Hillclimb measurement harness: lower ONE cell (small-depth, scan-unrolled)
and report per-layer-unit collective/flops/bytes + full-cell memory.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch grok-1-314b --shape train_4k
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--full", action="store_true", help="also compile full depth for memory")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.launch import mesh as mesh_lib
    from repro.launch import shapes as shapes_lib
    from repro.launch.dryrun import (
        _cost_dict, _layer_variants, _lower_lm, _mem_dict, collective_bytes,
    )
    from repro.models.registry import get_config

    cfg = get_config(args.arch)
    mesh = mesh_lib.make_production_mesh(multi_pod=(args.mesh == "multi"))
    shape = shapes_lib.SHAPES[args.shape]

    cfg1, cfg2, units = _layer_variants(cfg)
    _, c1 = _lower_lm(cfg1, shape, mesh)
    r1 = dict(cost=_cost_dict(c1.cost_analysis()), coll=collective_bytes(c1.as_text()))
    _, c2 = _lower_lm(cfg2, shape, mesh)
    r2 = dict(cost=_cost_dict(c2.cost_analysis()), coll=collective_bytes(c2.as_text()))

    per_layer_coll = {k: (r2["coll"].get(k, 0) - r1["coll"].get(k, 0))
                      for k in set(r1["coll"]) | set(r2["coll"])}
    per_layer_flops = r2["cost"]["flops"] - r1["cost"]["flops"]
    per_layer_bytes = r2["cost"]["bytes_accessed"] - r1["cost"]["bytes_accessed"]
    total_coll = {k: r1["coll"].get(k, 0) + (units - 1) * v for k, v in per_layer_coll.items()}

    out = dict(
        tag=args.tag, arch=args.arch, shape=args.shape, mesh=args.mesh, units=units,
        per_layer=dict(flops=per_layer_flops, bytes=per_layer_bytes,
                       collectives_gb={k: round(v / 1e9, 3) for k, v in per_layer_coll.items()}),
        total_collectives_gb={k: round(v / 1e9, 2) for k, v in total_coll.items()},
        total_flops=r1["cost"]["flops"] + (units - 1) * per_layer_flops,
        total_bytes=r1["cost"]["bytes_accessed"] + (units - 1) * per_layer_bytes,
    )
    if args.full:
        _, cf = _lower_lm(cfg, shape, mesh)
        out["memory"] = _mem_dict(cf.memory_analysis())
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
