"""Plan pricer CLI: measure or lower-and-cost one retrieval plan.

This used to be an LLM-arch lowering harness that hard-coded
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` at import time.
Its lower-and-cost loop now lives in `core/autotune.price_plan` (the
autotuner's candidate pricer), this CLI points it at the retrieval spine,
and the host-device override is opt-in via ``--host-devices``.

    # wall-clock price of one plan (the autotuner's measure mode)
    PYTHONPATH=src python -m benchmarks.hillclimb --engine eq --n 8192 --q 64 \
        --use-kernel --tile tile_n=1024

    # XLA cost-model price without executing (the old lower-and-cost loop)
    PYTHONPATH=src python -m benchmarks.hillclimb --engine cosine --mode lower

    # full greedy autotune of the shape, winner printed as a TunedEntry
    PYTHONPATH=src python -m benchmarks.hillclimb --engine eq --tune --budget 16
"""
import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="eq")
    ap.add_argument("--layout", default="wide", choices=["wide", "packed"])
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--q", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--mode", default="measure", choices=["measure", "lower"])
    ap.add_argument("--use-kernel", action="store_true",
                    help="price the Pallas kernel path (required for --tile)")
    ap.add_argument("--tile", action="append", default=[], metavar="KNOB=V",
                    help="tile override, e.g. --tile tile_n=1024 (repeatable)")
    ap.add_argument("--tune", action="store_true",
                    help="run the greedy autotuner instead of pricing one plan")
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", default=None,
                    help="autotune cache JSON to read/write (--tune)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="opt-in --xla_force_host_platform_device_count "
                         "(applied before the backend initialises)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    import numpy as np

    from repro.core import autotune as autotune_lib
    from repro.core import engines
    from repro.core import plan as plan_lib
    from repro.core.types import SignatureLayout

    if args.host_devices is not None:
        autotune_lib.setup_platform(host_devices=args.host_devices)

    model = engines.get(args.engine)
    rng = np.random.default_rng(args.seed)
    if model.example is None:
        raise SystemExit(f"engine {args.engine!r} provides no example data")
    data, queries, mc = model.example(rng, args.n, args.q)

    if args.tune:
        cache = autotune_lib.AutotuneCache(args.cache)
        entry = autotune_lib.tune(
            model, data, queries, args.k, mc,
            signature_layout=args.layout,
            budget=args.budget, repeats=args.repeats, cache=cache)
        out = dict(tag=args.tag, mode="tune", engine=args.engine,
                   n=args.n, q=args.q, k=args.k,
                   fingerprint=autotune_lib.hardware_fingerprint(),
                   entry=entry.to_dict())
        print(json.dumps(out, indent=1))
        return

    tiles = {}
    for item in args.tile:
        knob, _, value = item.partition("=")
        if not value:
            raise SystemExit(f"--tile wants KNOB=VALUE, got {item!r}")
        tiles[knob] = int(value)
    if tiles and not args.use_kernel:
        raise SystemExit("--tile prices the kernel path: add --use-kernel")

    sig_layout = SignatureLayout(args.layout)
    wide = model.prepare_data(data)
    mc = model.resolve_max_count(wide, mc)
    stored = (model.pack_data(wide)
              if sig_layout is SignatureLayout.PACKED else wide)
    q_stored = model.prepare_queries_for(queries, sig_layout)
    plan = plan_lib.plan_search(
        model, args.k, mc, use_kernel=args.use_kernel,
        signature_layout=sig_layout, tile_overrides=tiles or None)
    price = autotune_lib.price_plan(plan, stored, q_stored, mode=args.mode,
                                    repeats=args.repeats)
    out = dict(tag=args.tag, engine=args.engine, layout=args.layout,
               n=args.n, q=args.q, k=args.k, use_kernel=args.use_kernel,
               tiles=tiles, price=price, plan=plan.describe())
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
