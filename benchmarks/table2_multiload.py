"""Paper Table II/III: multiple-loading scalability + extra-step costs."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, ann_dataset, query_sigs, timeit, timeit_host
from repro.core import GenieIndex


def run() -> list[Row]:
    pts, _, params, sigs = ann_dataset()
    idx = GenieIndex.build_lsh(sigs, use_kernel=False)
    qs, _ = query_sigs(params, pts, np.arange(128) % pts.shape[0])
    qs_j = jnp.asarray(qs)
    rows = []
    base = timeit(lambda: idx.search(qs_j, k=100).ids)
    rows.append(Row("table2.single_load", base, ""))
    for parts in (2, 4, 8):
        us = timeit(lambda p=parts: idx.search_multiload(qs_j, k=100, n_parts=p).ids)
        rows.append(Row(f"table2.multiload_p{parts}", us, f"vs_single={us/base:.2f}x"))
    # Table III extra steps: per-part transfer + final merge
    part = np.asarray(sigs[: sigs.shape[0] // 4])
    rows.append(Row("table3.part_transfer", timeit_host(
        lambda: jax.device_put(part).block_until_ready(), iters=3), f"bytes={part.nbytes}"))
    from repro.core import cpq as _cpq
    ids = jnp.tile(jnp.arange(100, dtype=jnp.int32)[None], (128, 4))
    cnts = jnp.tile(jnp.arange(400, 0, -1, dtype=jnp.int32)[None, :400], (128, 1))
    merge_fn = jax.jit(lambda i, c: _cpq.topk_from_candidates(i, c, 100)[0])
    rows.append(Row("table3.result_merge", timeit(merge_fn, ids, cnts), "4 parts x k=100"))
    return rows
