"""Serve-latency micro-benchmark: p50 query latency, cached vs uncached plan.

The unified planner (core/plan.py) caches compiled executables per
(engine, layout shape, k, method, use_kernel), so a serving process pays the
trace/compile cost once per plan shape and every later query reuses the
compiled program.  This benchmark drives `RetrievalService.search` end to
end (hash -> plan -> execute -> MLE) and reports

    BENCH {"name": "serve_latency", ...}

with the first-search latency on a cold plan cache (trace + compile + run),
the p50/p90 of warm repeat searches (cache hits), and the measured speedup.
The gate is deliberately loose -- a warm search merely must not be *slower*
than the cold one -- because CPU CI wall-times are noisy; the interesting
number is the ratio, consumed by tools/ci.sh and EXPERIMENTS-style tracking.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import Row


def _one_search(svc, q, k):
    import jax

    t0 = time.perf_counter()
    res, _ = svc.search(None, k=k, embeddings=q)
    jax.block_until_ready((res.ids, res.counts))
    return (time.perf_counter() - t0) * 1e6


def run(n: int = 8192, d: int = 16, m: int = 64, batches: int = 4,
        q_batch: int = 64, k: int = 10, repeats: int = 30) -> list[Row]:
    from repro.core import plan as plan_lib
    from repro.serve.retrieval import RetrievalService

    rng = np.random.default_rng(0)
    pts = rng.standard_normal((n, d)).astype(np.float32)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=m)
    per = n // batches
    for i in range(batches):
        svc.add(list(range(i * per, (i + 1) * per)),
                embeddings=pts[i * per:(i + 1) * per])
    q = pts[rng.integers(0, n, q_batch)] + 0.01

    plan_lib.clear_plan_cache()
    uncached_us = _one_search(svc, q, k)            # trace + compile + run
    warm_us = sorted(_one_search(svc, q, k) for _ in range(repeats))
    p50 = warm_us[len(warm_us) // 2]
    p90 = warm_us[min(len(warm_us) - 1, int(len(warm_us) * 0.9))]
    # p99 reported alongside p50/p90: the front-end benchmark
    # (bench_frontend.py) gates on tail latency, so the serial baseline
    # exposes the same percentile (nearest-rank over the warm repeats)
    p99 = warm_us[min(len(warm_us) - 1, int(len(warm_us) * 0.99))]

    report = dict(
        name="serve_latency",
        corpus=n, q_batch=q_batch, k=k, m=m, segments=batches,
        uncached_first_us=round(uncached_us, 1),
        cached_p50_us=round(p50, 1),
        cached_p90_us=round(p90, 1),
        cached_p99_us=round(p99, 1),
        plan_cache_entries=plan_lib.plan_cache_size(),
        speedup_cold_over_warm=round(uncached_us / max(p50, 1e-9), 2),
        warm_not_slower=bool(p50 <= uncached_us * 1.5),
    )
    print("BENCH " + json.dumps(report), flush=True)
    _LAST_REPORT.update(report)
    return [
        Row("serve_latency.uncached_first", uncached_us,
            f"entries={report['plan_cache_entries']}"),
        Row("serve_latency.cached_p50", p50,
            f"speedup={report['speedup_cold_over_warm']}"),
    ]


_LAST_REPORT: dict = {}


def main() -> None:
    for r in run():
        print(r.csv())
    if not _LAST_REPORT.get("warm_not_slower"):
        raise SystemExit(
            f"plan cache not effective: warm p50 "
            f"{_LAST_REPORT.get('cached_p50_us')}us vs first "
            f"{_LAST_REPORT.get('uncached_first_us')}us"
        )


if __name__ == "__main__":
    main()
