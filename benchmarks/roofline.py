"""Roofline analysis (deliverable g): three-term model per (arch x shape x
mesh) cell from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs / peak_FLOPs            (per chip, bf16)
    memory term     = HLO_bytes / HBM_bw                (per chip)
    collective term = collective_bytes / ICI_link_bw    (per chip)

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (per-chip aggregate used as-is; a 2D-torus chip has more links, so this
is conservative).  HLO_FLOPs/bytes come from the scan-unrolled small-depth
extrapolation (see launch/dryrun.py) because XLA's cost analysis counts a
while-loop body once.  The dominant term approximates the step time on real
hardware assuming perfect overlap of the other two.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link, per chip

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def load_cells(pattern: str = "*") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(REPORT_DIR, f"{pattern}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyse(cell: dict, chips: int) -> dict | None:
    """Three roofline terms (seconds, per step) for one dry-run cell."""
    if not cell.get("ok") or cell.get("skipped"):
        return None
    ex = cell.get("extrapolated") or {}
    cost = ex.get("cost") or cell.get("cost") or {}
    coll = ex.get("collectives") or cell.get("collectives") or {}
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes_accessed", 0.0)
    coll_bytes = float(sum(coll.values()))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll_bytes / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_collective)
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = cell.get("model_flops", 0.0)
    hlo_global = flops * chips
    out = dict(
        name=cell.get("name"), shape=cell.get("shape"), mesh=cell.get("mesh"),
        kind=cell.get("kind"),
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        dominant=dominant, step_seconds_lb=bound,
        model_flops=model_flops, hlo_flops_global=hlo_global,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        # roofline fraction: useful model FLOPs vs what the chips could do in
        # the bound time (the score axis)
        roofline_fraction=(model_flops / (chips * PEAK_FLOPS * bound)) if bound else 0.0,
        mem_gb_per_dev=(cell.get("memory", {}).get("temp_size_in_bytes", 0)
                        + cell.get("memory", {}).get("argument_size_in_bytes", 0)) / 1e9,
        fits_16gb=(cell.get("memory", {}).get("temp_size_in_bytes", 0)
                   + cell.get("memory", {}).get("argument_size_in_bytes", 0)) < 16e9,
        collectives=coll,
    )
    return out


def table(mesh: str = "single") -> list[dict]:
    chips = 256 if mesh == "single" else 512
    rows = []
    for cell in load_cells():
        if cell.get("mesh") != mesh:
            continue
        r = analyse(cell, chips)
        if r:
            rows.append(r)
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'cell':42s} {'comp(ms)':>9s} {'mem(ms)':>9s} {'coll(ms)':>9s} "
           f"{'dominant':>10s} {'MFU-frac':>9s} {'useful':>7s} {'GB/dev':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["kind"], r["name"], r["shape"])):
        lines.append(
            f"{r['kind']+':'+r['name']+':'+r['shape']:42s} "
            f"{r['t_compute']*1e3:9.2f} {r['t_memory']*1e3:9.2f} "
            f"{r['t_collective']*1e3:9.2f} {r['dominant']:>10s} "
            f"{r['roofline_fraction']:9.3f} {r['useful_ratio']:7.2f} "
            f"{r['mem_gb_per_dev']:7.2f}"
        )
    return "\n".join(lines)


def main() -> None:
    for mesh in ("single", "multi"):
        rows = table(mesh)
        if rows:
            print(f"\n=== Roofline ({mesh} mesh, {256 if mesh=='single' else 512} chips) ===")
            print(format_table(rows))


if __name__ == "__main__":
    main()
