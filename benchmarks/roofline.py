"""Roofline analysis (deliverable g): three-term model per (arch x shape x
mesh) cell from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs / peak_FLOPs            (per chip, bf16)
    memory term     = HLO_bytes / HBM_bw                (per chip)
    collective term = collective_bytes / ICI_link_bw    (per chip)

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (per-chip aggregate used as-is; a 2D-torus chip has more links, so this
is conservative).  HLO_FLOPs/bytes come from the scan-unrolled small-depth
extrapolation (see launch/dryrun.py) because XLA's cost analysis counts a
while-loop body once.  The dominant term approximates the step time on real
hardware assuming perfect overlap of the other two.

`run()` adds the signature-storage roofline (core/packing.py): for each
engine with a PACKED layout (COSINE sign-bit words, TANIMOTO uint8 buckets)
it models the bytes the match phase moves -- signatures + queries read once,
counts written once -- under WIDE vs PACKED storage, times both reference
match paths, and emits a ``BENCH {json}`` line.  The match phase is
memory-bound (one compare per signature byte), so bytes-moved is the
roofline axis that matters; `main()` gates packed bytes-per-object at
<= 1/4 of wide for both engines (tools/ci.sh).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link, per chip

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def load_cells(pattern: str = "*") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(REPORT_DIR, f"{pattern}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyse(cell: dict, chips: int) -> dict | None:
    """Three roofline terms (seconds, per step) for one dry-run cell."""
    if not cell.get("ok") or cell.get("skipped"):
        return None
    ex = cell.get("extrapolated") or {}
    cost = ex.get("cost") or cell.get("cost") or {}
    coll = ex.get("collectives") or cell.get("collectives") or {}
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes_accessed", 0.0)
    coll_bytes = float(sum(coll.values()))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll_bytes / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_collective)
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = cell.get("model_flops", 0.0)
    hlo_global = flops * chips
    out = dict(
        name=cell.get("name"), shape=cell.get("shape"), mesh=cell.get("mesh"),
        kind=cell.get("kind"),
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        dominant=dominant, step_seconds_lb=bound,
        model_flops=model_flops, hlo_flops_global=hlo_global,
        useful_ratio=(model_flops / hlo_global) if hlo_global else 0.0,
        # roofline fraction: useful model FLOPs vs what the chips could do in
        # the bound time (the score axis)
        roofline_fraction=(model_flops / (chips * PEAK_FLOPS * bound)) if bound else 0.0,
        mem_gb_per_dev=(cell.get("memory", {}).get("temp_size_in_bytes", 0)
                        + cell.get("memory", {}).get("argument_size_in_bytes", 0)) / 1e9,
        fits_16gb=(cell.get("memory", {}).get("temp_size_in_bytes", 0)
                   + cell.get("memory", {}).get("argument_size_in_bytes", 0)) < 16e9,
        collectives=coll,
    )
    return out


def table(mesh: str = "single") -> list[dict]:
    chips = 256 if mesh == "single" else 512
    rows = []
    for cell in load_cells():
        if cell.get("mesh") != mesh:
            continue
        r = analyse(cell, chips)
        if r:
            rows.append(r)
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'cell':42s} {'comp(ms)':>9s} {'mem(ms)':>9s} {'coll(ms)':>9s} "
           f"{'dominant':>10s} {'MFU-frac':>9s} {'useful':>7s} {'GB/dev':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["kind"], r["name"], r["shape"])):
        lines.append(
            f"{r['kind']+':'+r['name']+':'+r['shape']:42s} "
            f"{r['t_compute']*1e3:9.2f} {r['t_memory']*1e3:9.2f} "
            f"{r['t_collective']*1e3:9.2f} {r['dominant']:>10s} "
            f"{r['roofline_fraction']:9.3f} {r['useful_ratio']:7.2f} "
            f"{r['mem_gb_per_dev']:7.2f}"
        )
    return "\n".join(lines)


def print_tables() -> None:
    for mesh in ("single", "multi"):
        rows = table(mesh)
        if rows:
            print(f"\n=== Roofline ({mesh} mesh, {256 if mesh=='single' else 512} chips) ===")
            print(format_table(rows))


# ---------------------------------------------------------------------------
# Signature-storage roofline: match-phase bytes moved, WIDE vs PACKED
# ---------------------------------------------------------------------------

def _match_phase_bytes(data, queries, q: int, n: int) -> float:
    """Bytes the match phase moves: signatures + queries read once, int32
    counts written once.  This is the HBM-traffic model the packed layout
    attacks; compute per byte is constant, so the ratio is the speedup bound."""
    return float(data.size * data.dtype.itemsize
                 + queries.size * queries.dtype.itemsize
                 + q * n * 4)


def run(n: int = 4096, q: int = 128, v: int = 2048, m: int = 64) -> list:
    """Signature-storage roofline for the packable engines.

    CPU wall-times here are relative evidence (benchmarks/common.py); the
    load-bearing numbers are the analytic bytes-moved and the storage
    bytes-per-object, both exact.
    """
    import jax
    import numpy as np

    from benchmarks.common import Row, timeit
    from repro.core import engines as engines_lib
    from repro.core.types import Engine, SignatureLayout

    rng = np.random.default_rng(0)
    raw = {
        # raw float vectors; prepare_data sign-quantizes to int8 [N, V]
        Engine.COSINE: rng.standard_normal((n + q, v)).astype(np.float32),
        # minhash bucket ids within the packed uint8 domain (<= 253)
        Engine.TANIMOTO: rng.integers(0, 200, size=(n + q, m), dtype=np.int32),
    }
    rows, engines_rep = [], {}
    for engine, pts in raw.items():
        model = engines_lib.get(engine)
        wide_d = model.prepare_data(pts[:n])
        wide_q = model.prepare_queries_for(pts[n:], SignatureLayout.WIDE)
        packed_d = model.pack_data(wide_d)
        packed_q = model.prepare_queries_for(pts[n:], SignatureLayout.PACKED)
        wide_match = jax.jit(model.match_fn(False, SignatureLayout.WIDE))
        packed_match = jax.jit(model.match_fn(False, SignatureLayout.PACKED))

        wide_bytes = _match_phase_bytes(wide_d, wide_q, q, n)
        packed_bytes = _match_phase_bytes(packed_d, packed_q, q, n)
        wide_us = timeit(wide_match, wide_d, wide_q)
        packed_us = timeit(packed_match, packed_d, packed_q)
        wide_bpo = wide_d.size * wide_d.dtype.itemsize / n
        packed_bpo = packed_d.size * packed_d.dtype.itemsize / n
        engines_rep[engine.value] = dict(
            n=n, q=q, width=int(wide_d.shape[1]),
            bytes_per_object_wide=wide_bpo,
            bytes_per_object_packed=packed_bpo,
            storage_ratio=round(packed_bpo / wide_bpo, 4),
            match_bytes_wide=wide_bytes,
            match_bytes_packed=packed_bytes,
            bytes_reduction=round(wide_bytes / packed_bytes, 2),
            wide_us=round(wide_us, 1),
            packed_us=round(packed_us, 1),
            achieved_gbps_wide=round(wide_bytes / wide_us / 1e3, 3),
            achieved_gbps_packed=round(packed_bytes / packed_us / 1e3, 3),
        )
        rows.append(Row(f"signature_roofline.{engine.value}.wide", wide_us,
                        f"bytes={wide_bytes:.0f}"))
        rows.append(Row(f"signature_roofline.{engine.value}.packed", packed_us,
                        f"reduction={engines_rep[engine.value]['bytes_reduction']}x"))
    report = dict(
        name="signature_roofline",
        engines=engines_rep,
        # gates consumed by main() / tools/ci.sh
        storage_quarter_or_better=all(
            r["bytes_per_object_packed"] <= r["bytes_per_object_wide"] / 4
            for r in engines_rep.values()),
        match_bytes_halved_somewhere=any(
            r["bytes_reduction"] >= 2.0 for r in engines_rep.values()),
    )
    print("BENCH " + json.dumps(report), flush=True)
    _LAST_REPORT.update(report)
    return rows


_LAST_REPORT: dict = {}


def main() -> None:
    for r in run():
        print(r.csv())
    if not _LAST_REPORT.get("storage_quarter_or_better"):
        raise SystemExit(
            "signature packing regressed: packed bytes-per-object exceeds "
            "1/4 of wide for a packable engine -- "
            + json.dumps(_LAST_REPORT.get("engines", {}))
        )
    if not _LAST_REPORT.get("match_bytes_halved_somewhere"):
        raise SystemExit(
            "signature packing regressed: no engine halves match-phase "
            "bytes moved -- " + json.dumps(_LAST_REPORT.get("engines", {}))
        )
    print_tables()


if __name__ == "__main__":
    main()
