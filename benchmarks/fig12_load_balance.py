"""Paper Fig 12: load balance by splitting long postings lists.

The TPU analogue of GPU block imbalance is padding waste: the unsplit engine
pads every scanned list to the global max length.  We index a skewed (Zipf)
keyword distribution and compare the tiled postings scan with 4K sub-list
splitting vs without."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core.postings import PostingsIndex


def run() -> list[Row]:
    rng = np.random.default_rng(5)
    n, m, kw_space = 20_000, 8, 256
    # Zipfian keywords: a few extremely long postings lists (paper's Adult case)
    ranks = np.arange(1, kw_space + 1)
    probs = 1.0 / ranks**1.2
    probs /= probs.sum()
    keywords = rng.choice(kw_space, size=(n, m), p=probs).astype(np.int32)
    pidx = PostingsIndex.build(keywords, n_keywords=kw_space)
    q = keywords[:16]
    rows = []
    for limit, tag in ((pidx.stats.max_list_len, "no_lb"), (4096, "lb4096"), (1024, "lb1024")):
        tiles, tile_kw = pidx.split_tiles(limit=limit)
        pad_ratio = tiles.size / max(pidx.stats.total_postings, 1)
        us = timeit(
            lambda t=jnp.asarray(tiles), tk=jnp.asarray(tile_kw): pidx.scan_counts_tiled(
                t, tk, jnp.asarray(q)
            )
        )
        rows.append(Row(f"fig12.{tag}", us,
                        f"tiles={tiles.shape[0]};pad_ratio={pad_ratio:.2f};"
                        f"max_list={pidx.stats.max_list_len}"))
    return rows
