"""Paper Table I: time profiling of GENIE stages (index build, index
transfer, query transfer, match, select)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, ann_dataset, query_sigs, timeit, timeit_host
from repro.core import GenieIndex, cpq, match
from repro.core.types import SearchParams


def run() -> list[Row]:
    pts, _, params, sigs = ann_dataset()
    qs, _ = query_sigs(params, pts, np.arange(128) % pts.shape[0])
    rows = []
    rows.append(Row("table1.index_build", timeit_host(
        lambda: GenieIndex.build_lsh(np.asarray(sigs), use_kernel=False), iters=1), ""))
    rows.append(Row("table1.index_transfer", timeit_host(
        lambda: jax.device_put(sigs).block_until_ready(), iters=3), f"bytes={sigs.nbytes}"))
    rows.append(Row("table1.query_transfer", timeit_host(
        lambda: jax.device_put(qs).block_until_ready(), iters=3), f"bytes={qs.nbytes}"))
    sigs_j, qs_j = jnp.asarray(sigs), jnp.asarray(qs)
    match_fn = jax.jit(match.match_eq)
    rows.append(Row("table1.query_match", timeit(match_fn, sigs_j, qs_j), ""))
    counts = match_fn(sigs_j, qs_j)
    p = SearchParams(k=100, max_count=sigs.shape[1])
    sel = jax.jit(lambda c: cpq.cpq_select(c, p).ids)
    rows.append(Row("table1.query_select", timeit(sel, counts),
                    "match dominates, as in the paper"))
    return rows
