"""Serving-front-end throughput benchmark: coalesced continuous batching vs
serial per-request search under synthetic multi-tenant traffic.

Traffic model: Poisson arrivals (exponential inter-arrival gaps) over a
skewed tenant mix -- a few hot tenants dominate, as in real serving -- each
request a small query batch at k=10.  The serial baseline answers the same
request stream back-to-back through `RetrievalService.search` (one device
dispatch per request); the batched run pushes the stream through
`ServingFrontend.submit`, which coalesces compatible requests (same tenant x
plan-cache key) into stacked dispatches.  Both paths run on warmed plan
caches (the serial k and the front-end's k-bucket plan shapes are traced
before timing), so the measured gap is pure dispatch amortisation -- the
GENIE multi-query pass serving many requests per device scan.

Prints

    BENCH {"name": "frontend_throughput", ...}

with the serial/batched wall times, the speedup (gated >= 2x in tools/ci.sh
via main()), per-tenant-aggregate p50/p99 request latency, and the
batch-occupancy / coalesce-ratio numbers from `frontend.stats()`.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import Row

TENANTS = ("hot", "warm", "mild", "cold")
MIX = (0.55, 0.25, 0.12, 0.08)      # skewed: two tenants carry 80% of load


def _build(seed: int = 0, corpus: int = 2048, d: int = 16, m: int = 32):
    from repro.serve import RetrievalService

    rng = np.random.default_rng(seed)
    services, points = {}, {}
    for i, name in enumerate(TENANTS):
        pts = rng.standard_normal((corpus, d)).astype(np.float32)
        svc = RetrievalService(embed_fn=np.asarray, m_override=m, seed=i)
        per = corpus // 4
        for j in range(4):      # 4 sealed segments per tenant
            svc.add(list(range(j * per, (j + 1) * per)),
                    embeddings=pts[j * per:(j + 1) * per])
        services[name], points[name] = svc, pts
    return services, points, rng


def _traffic(rng, points, requests: int, q_batch: int, mean_gap_us: float):
    """(tenant, query rows, arrival gap seconds) per request: skewed tenant
    choice, Poisson (exponential-gap) arrivals."""
    names = rng.choice(len(TENANTS), size=requests, p=MIX)
    gaps = rng.exponential(mean_gap_us * 1e-6, size=requests)
    stream = []
    for i in range(requests):
        name = TENANTS[int(names[i])]
        lo = int(rng.integers(0, len(points[name]) - q_batch))
        stream.append((name, points[name][lo:lo + q_batch] + 0.01,
                       float(gaps[i])))
    return stream


def run(requests: int = 192, q_batch: int = 1, k: int = 10,
        mean_gap_us: float = 100.0, max_batch: int = 32,
        corpus: int = 512) -> list[Row]:
    import jax

    from repro.core import plan as plan_lib
    from repro.serve import ServingFrontend

    services, points, rng = _build(corpus=corpus)
    stream = _traffic(rng, points, requests, q_batch, mean_gap_us)

    # warm BOTH plan shapes outside the timed regions: the serial path runs
    # at k, the front-end dispatches at the k-bucket (16 for k=10) -- an
    # unwarmed side would be charged a trace+compile it never pays again
    for name, svc in services.items():
        q = points[name][:q_batch] + 0.01
        for warm_k in (k, plan_lib.k_bucket(k)):
            res, _ = svc.search(None, k=warm_k, embeddings=q)
            jax.block_until_ready((res.ids, res.counts))

    # warm the front-end's bucketed dispatch shapes too: the coalescer pads
    # stacked rows to power-of-two buckets <= max_batch, so trace every
    # bucket once (the plan/executable cache is global and the plan shape is
    # tenant-independent, so one tenant warms them all) -- the timed run
    # below then starts fully warm, symmetric with the warmed serial path
    svc0, pts0 = services[TENANTS[0]], points[TENANTS[0]]
    bucket = 1
    while bucket <= max_batch:
        q = np.repeat(pts0[:1] + 0.01, bucket, axis=0)
        res, _ = svc0.search(None, k=plan_lib.k_bucket(k), embeddings=q)
        jax.block_until_ready((res.ids, res.counts))
        bucket *= 2

    # -- serial baseline: one dispatch per request, back-to-back ----------
    t0 = time.perf_counter()
    for name, q, _gap in stream:
        res, _ = services[name].search(None, k=k, embeddings=q)
        jax.block_until_ready((res.ids, res.counts))
    serial_s = time.perf_counter() - t0

    # -- batched: the same stream through the coalescing front-end --------
    # max_batch is a power of two, so full chunks dispatch with zero padding
    # (only the final partial chunk of a pile-up pads to its row bucket)
    with ServingFrontend(max_queue=2 * requests, max_wait_us=3000,
                         max_batch=max_batch) as fe:
        for name, svc in services.items():
            fe.register(name, svc)
        t0 = time.perf_counter()
        futs = []
        for name, q, gap in stream:
            if gap > 0:
                time.sleep(gap)         # Poisson offered load
            futs.append(fe.submit(name, None, k=k, embeddings=q))
        for f in futs:
            f.result(timeout=600)
        batched_s = time.perf_counter() - t0
        stats = fe.stats()

    speedup = serial_s / max(batched_s, 1e-9)
    report = dict(
        name="frontend_throughput",
        tenants=len(TENANTS), requests=requests, q_batch=q_batch, k=k,
        corpus=corpus, max_batch=max_batch, mean_gap_us=mean_gap_us,
        serial_s=round(serial_s, 4),
        batched_s=round(batched_s, 4),
        speedup=round(speedup, 2),
        dispatches=stats["dispatches"],
        coalesce_ratio=stats["coalesce_ratio"],
        batch_occupancy=stats["batch_occupancy"],
        p50_ms=stats["p50_ms"],
        p99_ms=stats["p99_ms"],
        queue_high_water=stats["queue_high_water"],
        batched_2x=bool(speedup >= 2.0),
    )
    print("BENCH " + json.dumps(report), flush=True)
    _LAST_REPORT.update(report)
    per_req_serial = serial_s / requests * 1e6
    per_req_batched = batched_s / requests * 1e6
    return [
        Row("frontend.serial_per_request", per_req_serial,
            f"dispatches={requests}"),
        Row("frontend.batched_per_request", per_req_batched,
            f"dispatches={report['dispatches']} speedup={report['speedup']}"),
    ]


_LAST_REPORT: dict = {}


def main() -> None:
    for r in run():
        print(r.csv())
    if not _LAST_REPORT.get("batched_2x"):
        raise SystemExit(
            f"continuous batching below the 2x gate: serial "
            f"{_LAST_REPORT.get('serial_s')}s vs batched "
            f"{_LAST_REPORT.get('batched_s')}s "
            f"(speedup {_LAST_REPORT.get('speedup')})"
        )


if __name__ == "__main__":
    main()
