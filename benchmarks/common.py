"""Shared benchmark harness.

CPU-container sizing: the paper's datasets are 1M-36M objects on a GTX Titan
X; here every dataset is a deterministic synthetic stand-in at ~20K objects
and the engines run their pure-XLA paths (use_kernel=False -- interpret-mode
Pallas would time the Python interpreter, not the algorithm).  Wall-times are
therefore *relative* evidence (c-PQ vs SPQ vs sort orderings, scaling slopes);
the absolute TPU numbers live in the dry-run roofline (EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Callable

import jax
import numpy as np


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def timeit_host(fn: Callable, *args, warmup: int = 0, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(rows: list[Row]) -> None:
    for r in rows:
        print(r.csv())
        sys.stdout.flush()


# ---------------------------------------------------------------------------
# Shared synthetic datasets (built once, cached)
# ---------------------------------------------------------------------------

_CACHE: dict = {}


def ann_dataset(n: int = 20_000, d: int = 32, m: int = 64, seed: int = 7):
    """(points, labels, e2lsh params, signatures) -- SIFT-like stand-in."""
    key = ("ann", n, d, m, seed)
    if key not in _CACHE:
        import jax.numpy as jnp

        from repro.core.lsh import e2lsh
        from repro.data.pipeline import synthetic_points

        pts, labels = synthetic_points(n, d, n_clusters=64, seed=seed)
        params = e2lsh.make(jax.random.PRNGKey(seed), d=d, m=m, w=4.0, n_buckets=67)
        sigs = np.asarray(e2lsh.hash_points(params, jnp.asarray(pts)))
        _CACHE[key] = (pts, labels, params, sigs)
    return _CACHE[key]


def query_sigs(params, pts, idxs, noise=0.1, seed=11):
    import jax.numpy as jnp

    from repro.core.lsh import e2lsh

    rng = np.random.default_rng(seed)
    q = pts[idxs] + rng.standard_normal((len(idxs), pts.shape[1])).astype(np.float32) * noise
    return np.asarray(e2lsh.hash_points(params, jnp.asarray(q))), q
