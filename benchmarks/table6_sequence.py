"""Paper Tables VI/VII: sequence-search top-1 accuracy vs modification rate
and vs K, with latency (DBLP-like synthetic titles)."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core import GenieIndex
from repro.core.sa import ngram, verify
from repro.data.pipeline import mutate_sequence, synthetic_sequences


def _search_accuracy(seqs, idx, rate, K, n, v, nq=64):
    hits = 0
    qvs, targets = [], []
    for qi in range(nq):
        t = (qi * 37) % len(seqs)
        targets.append(t)
        qvs.append(ngram.count_vector(mutate_sequence(seqs[t], rate, seed=qi), n, v))
    qv = jnp.asarray(np.stack(qvs))
    res = idx.search(qv, k=K)
    ids = np.asarray(res.ids)
    # verify: exact edit distance on the K candidates, take best
    for qi, t in enumerate(targets):
        cand = [seqs[i] if i >= 0 else "" for i in ids[qi]]
        enc, lens = ngram.encode_sequences(cand, 48)
        qenc, qlen = ngram.encode_sequences([mutate_sequence(seqs[t], rate, seed=qi)], 48)
        out = verify.verify_topk(jnp.asarray(qenc[0]), jnp.int32(qlen[0]),
                                 jnp.asarray(enc), jnp.asarray(lens),
                                 jnp.asarray(np.asarray(res.counts[qi])), k=1, n=n)
        best = int(ids[qi][int(np.asarray(out["order"])[0])])
        hits += best == t
    return hits / nq, qv, res


def run() -> list[Row]:
    n, v = 3, 4096
    seqs = synthetic_sequences(5_000, length=40, seed=21)
    idx = GenieIndex.build_minsum(ngram.count_vectors(seqs, n, v), max_count=127,
                                  use_kernel=False)
    rows = []
    for rate in (0.1, 0.2, 0.3, 0.4):
        acc, qv, _ = _search_accuracy(seqs, idx, rate, K=32, n=n, v=v)
        us = timeit(lambda q=qv: idx.search(q, k=32).ids)
        rows.append(Row(f"table6.mod{rate}", us, f"top1_acc={acc:.3f};paper>=0.954@0.4"))
    for K in (8, 16, 32, 64):
        acc, qv, _ = _search_accuracy(seqs, idx, 0.3, K=K, n=n, v=v, nq=32)
        us = timeit(lambda q=qv, kk=K: idx.search(q, k=kk).ids)
        rows.append(Row(f"table7.K{K}", us, f"top1_acc={acc:.3f}"))
    return rows
