"""Checkpointing: atomic round-trip, bit-exact resume, pruning, elastic restore."""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.data.pipeline import DataConfig
from repro.models.registry import get_api, get_config
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainHParams
from repro.train import step as tsl


def _trainer(ckpt_dir, total_steps, fail_injector=None, seed=0):
    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    # NOTE: hp.total_steps stays fixed across resume phases -- it defines the
    # LR schedule, which must not change when a job restarts mid-run.
    hp = TrainHParams(optimizer=AdamWConfig(lr=1e-3), total_steps=10, warmup_steps=2)
    tc = TrainerConfig(total_steps=total_steps, ckpt_dir=ckpt_dir, ckpt_every=5,
                       log_every=5, async_checkpoint=False, seed=seed)
    data = DataConfig(global_batch=2, seq_len=32)
    return Trainer(cfg, api, hp, tc, data, fail_injector=fail_injector)


def test_save_restore_roundtrip(tmp_path):
    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    hp = TrainHParams()
    state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), hp)
    checkpointer.save(str(tmp_path), 3, state, extra=dict(data_step=3))
    assert checkpointer.latest_step(str(tmp_path)) == 3
    restored, manifest = checkpointer.restore(str(tmp_path), 3, state)
    assert manifest["extra"]["data_step"] == 3
    for a, b in zip(jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_resume_bit_exact(tmp_path):
    """10 straight steps == 5 steps + save + restore + 5 steps."""
    t1 = _trainer(None, 10)
    t1.run()
    straight = t1.final_state

    d = str(tmp_path / "ck")
    t2 = _trainer(d, 5)
    t2.run()
    t3 = _trainer(d, 10)
    t3.run()  # resumes from step 5
    resumed = t3.final_state
    for a, b in zip(jax.tree_util.tree_leaves(straight.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_atomic_no_tmp_left(tmp_path):
    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), TrainHParams())
    checkpointer.save(str(tmp_path), 1, state)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_prune_keeps_latest(tmp_path):
    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), TrainHParams())
    for s in (1, 2, 3, 4):
        checkpointer.save(str(tmp_path), s, state)
    checkpointer.prune(str(tmp_path), keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_async_checkpoint_joins(tmp_path):
    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), TrainHParams())
    t = checkpointer.save(str(tmp_path), 7, state, async_=True)
    t.join()
    assert checkpointer.latest_step(str(tmp_path)) == 7
