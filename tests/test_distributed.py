"""Multi-device tests (8 forced CPU devices via subprocess: jax locks the
device count at first init, so these run out-of-process)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_distributed_search_matches_oracle():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed, match, cpq
        from repro.core.types import SearchParams
        from repro.launch import mesh as mesh_lib
        for shape, axes in [((2,4), ('data','model')), ((2,2,2), ('pod','data','model'))]:
            mesh = mesh_lib.make_mesh(shape, axes)
            rng = np.random.default_rng(0)
            data = rng.integers(0, 6, (128, 16)).astype(np.int32)
            queries = rng.integers(0, 6, (4, 16)).astype(np.int32)
            params = SearchParams(k=7, max_count=16)
            for maker in (distributed.make_search_step, distributed.make_hierarchical_search_step):
                step = maker(mesh, params, match.match_eq)
                dd = jax.device_put(data, distributed.data_sharding(mesh))
                qq = jax.device_put(queries, distributed.replicated(mesh, 2))
                res = step(dd, qq)
                want = cpq.sort_select(match.match_eq(jnp.asarray(data), jnp.asarray(queries)), params)
                assert np.array_equal(np.asarray(res.counts), np.asarray(want.counts)), maker
        print('distributed search OK')
    """)


def test_sharded_train_step_matches_single_device():
    _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch import sharding as sh_lib
        from repro.launch import mesh as sh_lib_mesh
        from repro.models.registry import get_api, get_config
        from repro.train import step as tsl
        from repro.data.pipeline import DataConfig, SyntheticTokens

        cfg = get_config('phi3-mini-3.8b-smoke')
        api = get_api(cfg)
        hp = tsl.TrainHParams(remat=False)
        batch = SyntheticTokens(cfg, DataConfig(global_batch=4, seq_len=32)).batch(0)
        loss_single = tsl.make_loss_fn(cfg, api, hp)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        l0 = float(loss_single(params, batch)[0])

        mesh = sh_lib_mesh.make_mesh((4, 2), ('data', 'model'))
        with sh_lib_mesh.use_mesh(mesh):
            pshapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
            psh = sh_lib.params_shardings(pshapes, mesh, cfg.use_tp)
            bsh = sh_lib.batch_shardings({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh, cfg.use_tp)
            pp = jax.device_put(params, psh)
            bb = {k: jax.device_put(np.asarray(v), bsh[k]) for k, v in batch.items()}
            l1 = float(jax.jit(lambda p, b: loss_single(p, b)[0], in_shardings=(psh, bsh))(pp, bb))
        assert abs(l0 - l1) < 2e-2, (l0, l1)
        print('sharded loss matches single-device:', l0, l1)
    """)


def test_elastic_restore_across_mesh_shapes(tmp_path):
    _run(f"""
        import numpy as np, jax
        from repro.checkpoint import checkpointer
        from repro.launch import sharding as sh_lib
        from repro.launch import mesh as mesh_lib
        from repro.models.registry import get_api, get_config
        from repro.train import step as tsl

        cfg = get_config('phi3-mini-3.8b-smoke')
        api = get_api(cfg)
        state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), tsl.TrainHParams())
        checkpointer.save(r'{tmp_path}', 1, state, extra=dict(data_step=1))

        # restore onto a (2,4) mesh, then a (4,2) mesh: elastic reshard
        for shape in [(2, 4), (4, 2)]:
            mesh = mesh_lib.make_mesh(shape, ('data', 'model'))
            pshapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
            psh = sh_lib.params_shardings(pshapes, mesh, cfg.use_tp)
            ssh = sh_lib.state_shardings(jax.eval_shape(
                lambda: tsl.init_state(cfg, api, jax.random.PRNGKey(0), tsl.TrainHParams())), psh, mesh)
            restored, _ = checkpointer.restore(r'{tmp_path}', 1, state, ssh)
            for a, b in zip(jax.tree_util.tree_leaves(state.params),
                            jax.tree_util.tree_leaves(restored.params)):
                assert np.array_equal(np.asarray(a), np.asarray(b))
        print('elastic restore OK')
    """)
