"""Segment/merge invariants: SegmentedIndex == monolithic GenieIndex, exactly.

Segments partition the object set, so per-segment match counts are complete
and the cap-buffer merge is exact -- segmented search must return identical
ids *and* counts to a monolithic index over the concatenated data, for every
registered engine, every selection method, uneven segment sizes (including a
segment smaller than k), after compaction, and through the streamed
(multiload-host) path.  RetrievalService's old rebuild-on-add path is the
oracle for the serving-layer invariant.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import GenieIndex, SegmentedIndex, engines, merge
from repro.core.types import Engine, TopKMethod

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ALL_ENGINES = sorted(engines.available(), key=lambda e: e.value)

# uneven on purpose: a 1-row segment, a segment smaller than k, a big one
CUTS = [0, 3, 4, 40, 90, 101]


def _case(engine: Engine, n=101, q=4, seed=0):
    model = engines.get(engine)
    raw, queries, mc = model.example(np.random.default_rng(seed), n, q)
    return model, raw, queries, mc


def _segmented(engine, raw, mc, cuts=CUTS):
    seg = SegmentedIndex(engine=engine, max_count=mc, use_kernel=False)
    for a, b in zip(cuts, cuts[1:]):
        seg.add(raw[a:b])
    return seg


def _assert_same(got, want, label=""):
    assert np.array_equal(np.asarray(got.ids), np.asarray(want.ids)), label
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts)), label
    assert np.array_equal(np.asarray(got.threshold), np.asarray(want.threshold)), label


@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("method", [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT])
def test_segmented_equals_monolithic(engine, method):
    """Exact ids/counts parity across uneven segments for every engine and
    every selection method."""
    model, raw, queries, mc = _case(engine)
    mono = GenieIndex.build(engine, raw, max_count=mc, use_kernel=False)
    seg = _segmented(engine, raw, mc)
    assert seg.n_objects == mono.stats.n_objects
    got = seg.search(queries, k=9, method=method)
    want = mono.search(queries, k=9, method=method)
    _assert_same(got, want, f"{engine.value} {method.value}")


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_segmented_streamed_equals_monolithic(engine):
    """The multiload-host streaming path over heterogeneous segment sizes."""
    model, raw, queries, mc = _case(engine)
    mono = GenieIndex.build(engine, raw, max_count=mc, use_kernel=False)
    seg = _segmented(engine, raw, mc)
    got = seg.search_multiload(queries, k=9)
    _assert_same(got, mono.search(queries, k=9), engine.value)


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_segmented_after_compaction(engine):
    """Compaction coalesces adjacent segments without remapping ids."""
    model, raw, queries, mc = _case(engine)
    mono = GenieIndex.build(engine, raw, max_count=mc, use_kernel=False)
    want = mono.search(queries, k=9)
    seg = _segmented(engine, raw, mc)
    for max_segments in (3, 1):
        seg.compact(max_segments)
        assert len(seg.segments) == max_segments
        assert seg.n_objects == mono.stats.n_objects
        _assert_same(seg.search(queries, k=9), want,
                     f"{engine.value} compact({max_segments})")
    assert seg.compaction_count == 2


def test_segment_stats_accounting():
    model, raw, _, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)
    st = seg.stats
    assert st.n_segments == len(CUTS) - 1
    assert st.segment_rows == [b - a for a, b in zip(CUTS, CUTS[1:])]
    assert st.n_objects == 101 and sum(st.segment_rows) == 101
    assert len(st.segment_build_seconds) == st.n_segments
    assert all(s >= 0 for s in st.segment_build_seconds)
    assert st.compaction_count == 0
    seg.compact(2)
    st = seg.stats
    assert st.n_segments == 2 and st.compaction_count == 1
    assert st.compaction_seconds >= 0
    assert sum(st.segment_rows) == 101
    # monolithic stats keep the degenerate single-segment defaults
    mono = GenieIndex.build(Engine.EQ, raw, use_kernel=False)
    assert mono.stats.n_segments == 1 and mono.stats.compaction_count == 0


def test_segmented_add_validates_width():
    model, raw, _, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)
    with pytest.raises(ValueError, match="width"):
        seg.add(raw[:5, :8])


def test_segmented_rejects_empty_batch(rng):
    """An empty add() would seal a 0-row segment and poison every later
    search; it must raise instead (service layer included)."""
    from repro.serve.retrieval import RetrievalService

    model, raw, queries, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)
    with pytest.raises(ValueError, match="empty batch"):
        seg.add(raw[:0])
    seg.search(queries, k=3)                                   # still healthy
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    with pytest.raises(ValueError, match="empty batch"):
        svc.add([], embeddings=np.zeros((0, 8), np.float32))


def test_segmented_empty_and_bad_args():
    seg = SegmentedIndex(engine=Engine.EQ)
    with pytest.raises(ValueError, match=r"add\(\) first"):
        seg.search(np.zeros((1, 4), np.int32), k=1)
    with pytest.raises(ValueError, match=r"add\(\) first"):
        seg.search_multiload(np.zeros((1, 4), np.int32), k=1)
    with pytest.raises(ValueError, match="max_segments"):
        seg.compact(0)


def test_segmented_resolves_max_count_on_first_add():
    model, raw, queries, _ = _case(Engine.EQ)
    seg = SegmentedIndex(engine=Engine.EQ, use_kernel=False)   # no max_count
    seg.add(raw[:50])
    assert seg.max_count == raw.shape[1]                       # m, like build()
    seg.add(raw[50:])
    mono = GenieIndex.build(Engine.EQ, raw, use_kernel=False)
    _assert_same(seg.search(queries, k=7), mono.search(queries, k=7))


def test_merge_ragged_pads_when_fewer_candidates_than_k():
    model, raw, queries, mc = _case(Engine.EQ, n=5)
    seg = _segmented(Engine.EQ, raw, mc, cuts=[0, 2, 5])
    res = seg.search(queries, k=9)
    ids = np.asarray(res.ids)
    assert ids.shape == (4, 9)
    assert np.all(ids[:, 5:] == -1)                            # only 5 objects
    assert np.all(np.asarray(res.counts)[:, 5:] == -1)


def test_concat_data_pads_and_masks():
    model, raw, _, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)
    data, n = seg.concat_data(pad_multiple=8)
    assert n == 101 and data.shape[0] == 104
    assert np.array_equal(np.asarray(data[:101]), np.asarray(raw))
    assert np.all(np.asarray(data[101:]) == engines.get(Engine.EQ).pad_value)


# ---------------------------------------------------------------------------
# Serving layer: repeated add vs the old rebuild path as oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["e2lsh", "simhash", "minhash"])
def test_retrieval_service_add_matches_rebuild_oracle(scheme, rng):
    """B incremental adds == one monolithic rebuild over all signatures (the
    pre-segmentation behaviour), exact ids and counts, every paired engine."""
    import jax.numpy as jnp

    from repro.core import lsh as lsh_lib
    from repro.serve.retrieval import RetrievalService

    pts = rng.standard_normal((130, 16)).astype(np.float32)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), scheme=scheme,
                           m_override=96)
    for a, b in [(0, 30), (30, 37), (37, 90), (90, 130)]:
        svc.add(list(range(a, b)), embeddings=pts[a:b])
    assert len(svc) == 130
    assert svc.index_stats.n_segments == 4

    sch = lsh_lib.get_scheme(scheme)
    sigs = sch.hash_points(svc._params, jnp.asarray(pts))
    oracle = GenieIndex.build(sch.engine, sigs, max_count=svc.m)  # old rebuild

    q = pts[88:96] + 0.01
    res, sims = svc.search(None, k=5, embeddings=q)
    want = oracle.search(sch.hash_points(svc._params, jnp.asarray(q)), k=5)
    _assert_same(res, want, scheme)
    assert sims.shape == (8, 5)


def test_retrieval_service_compacts_past_max_segments(rng):
    from repro.serve.retrieval import RetrievalService

    pts = rng.standard_normal((120, 8)).astype(np.float32)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=32,
                           max_segments=3)
    for i in range(0, 120, 20):
        svc.add(list(range(i, i + 20)), embeddings=pts[i:i + 20])
    assert len(svc._index.segments) <= 3
    assert svc.index_stats.compaction_count >= 1
    res, _ = svc.search(None, k=1, embeddings=pts[100:105] + 0.001)
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(100, 105))


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------

def test_retrieval_service_rejects_dim_mismatch(rng):
    """Second add with a different embedding dim must raise, naming both dims
    (the LSH params are built once, from the first add's dim)."""
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    svc.add([0, 1], embeddings=rng.standard_normal((2, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="8.*16|16.*8"):
        svc.add([2], embeddings=rng.standard_normal((1, 8)).astype(np.float32))
    # search queries are validated against the same dim
    with pytest.raises(ValueError, match="dim"):
        svc.search(None, k=1, embeddings=rng.standard_normal((1, 8)).astype(np.float32))


def test_retrieval_service_rejects_row_count_mismatch(rng):
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    with pytest.raises(ValueError, match="row count"):
        svc.add([0, 1, 2], embeddings=rng.standard_normal((2, 16)).astype(np.float32))
    # search validates the same alignment when queries are supplied
    svc.add([0, 1], embeddings=rng.standard_normal((2, 16)).astype(np.float32))
    with pytest.raises(ValueError, match="row count"):
        svc.search([0, 1], k=1,
                   embeddings=rng.standard_normal((3, 16)).astype(np.float32))


@pytest.mark.parametrize("n_parts", [0, -1, -7])
def test_search_multiload_rejects_bad_n_parts(n_parts, rng):
    """n_parts=0 used to ZeroDivisionError and negatives were silently
    accepted; both must raise a ValueError naming n_parts."""
    model, raw, queries, mc = _case(Engine.EQ, n=20)
    idx = GenieIndex.build(Engine.EQ, raw, use_kernel=False)
    with pytest.raises(ValueError, match="n_parts"):
        idx.search_multiload(queries, k=3, n_parts=n_parts)


def test_build_seconds_measures_completed_build():
    """stats.build_seconds must time the materialised build (block_until_ready),
    not async dispatch; it is recorded and non-negative for every engine."""
    for eng in ALL_ENGINES:
        model, raw, _, mc = _case(eng, n=64)
        idx = GenieIndex.build(eng, raw, max_count=mc, use_kernel=False)
        assert idx.stats.build_seconds >= 0.0
        # the data is materialised by the time build() returns
        np.asarray(idx.data)


# ---------------------------------------------------------------------------
# Distributed segmented shard layout (subprocess: forced multi-device CPU)
# ---------------------------------------------------------------------------

def test_distributed_segmented_layout_parity():
    """A ragged (non-divisible) segmented corpus through the sharded search
    step: concat_data pads to mesh divisibility and n_objects masks the pad
    tail, so results equal the monolithic reference exactly."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SegmentedIndex, distributed, engines, cpq
        from repro.core.types import Engine, SearchParams
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        n_dev = 8
        for eng in (Engine.EQ, Engine.COSINE):
            model = engines.get(eng)
            raw, rawq, mc = model.example(np.random.default_rng(0), 101, 4)
            seg = SegmentedIndex(engine=eng, max_count=mc, use_kernel=False)
            for a, b in [(0, 3), (3, 40), (40, 101)]:
                seg.add(raw[a:b])
            data, n_objects = seg.concat_data(pad_multiple=n_dev)
            assert n_objects == 101 and data.shape[0] == 104
            queries = model.prepare_queries(rawq)
            mx = seg.max_count
            params = SearchParams(k=7, max_count=mx, use_kernel=False)
            step = distributed.make_search_step(mesh, params, eng,
                                                n_objects=n_objects)
            dd = jax.device_put(data, distributed.data_sharding(mesh))
            qq = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, distributed.replicated(mesh, 2)),
                queries)
            res = step(dd, qq)
            want = cpq.sort_select(
                model.reference(model.prepare_data(raw), queries), params)
            assert np.array_equal(np.asarray(res.ids), np.asarray(want.ids)), eng
            assert np.array_equal(np.asarray(res.counts),
                                  np.asarray(want.counts)), eng
            assert int(np.asarray(res.ids).max()) < 101
        print('distributed segmented parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "distributed segmented parity OK" in out.stdout
