import os
import sys

# Tests see the real (single) device; only the dry-run forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
