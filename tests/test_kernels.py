"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(1, 5, 3), (3, 130, 17), (8, 300, 64), (5, 257, 33)]  # (Q, N, m)


@pytest.mark.parametrize("q,n,m", SHAPES)
@pytest.mark.parametrize("dtype", [np.int32, np.int16])
def test_match_count_sweep(q, n, m, dtype, rng):
    d = rng.integers(0, 9, size=(n, m)).astype(dtype)
    s = rng.integers(0, 9, size=(q, m)).astype(dtype)
    got = np.asarray(ops.match_count(jnp.asarray(d), jnp.asarray(s), tile_q=8, tile_n=128))
    want = np.asarray(ref.match_eq(jnp.asarray(d.astype(np.int32)), jnp.asarray(s.astype(np.int32))))
    assert got.shape == (q, n)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("q,n,d", [(2, 100, 5), (4, 300, 14), (1, 129, 31)])
def test_range_count_sweep(q, n, d, rng):
    x = rng.integers(0, 64, size=(n, d)).astype(np.int32)
    lo = rng.integers(0, 48, size=(q, d)).astype(np.int32)
    hi = lo + rng.integers(0, 20, size=(q, d)).astype(np.int32)
    got = np.asarray(ops.range_count(jnp.asarray(x), jnp.asarray(lo), jnp.asarray(hi),
                                     tile_q=8, tile_n=128))
    want = np.asarray(ref.match_range(jnp.asarray(x), jnp.asarray(lo), jnp.asarray(hi)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("q,n,v", [(2, 90, 64), (3, 260, 200), (1, 40, 513)])
@pytest.mark.parametrize("dtype", [np.int32, np.int8])
def test_minsum_count_sweep(q, n, v, dtype, rng):
    dc = rng.integers(0, 4, size=(n, v)).astype(dtype)
    qc = rng.integers(0, 4, size=(q, v)).astype(dtype)
    got = np.asarray(ops.minsum_count(jnp.asarray(dc), jnp.asarray(qc),
                                      tile_q=8, tile_n=128, tile_v=128))
    want = np.asarray(ref.match_minsum(jnp.asarray(dc.astype(np.int32)),
                                       jnp.asarray(qc.astype(np.int32))))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("q,n,v", [(2, 90, 64), (4, 300, 256)])
def test_ip_count_sweep(q, n, v, rng):
    db = (rng.random((n, v)) < 0.3).astype(np.int8)
    qb = (rng.random((q, v)) < 0.3).astype(np.int8)
    got = np.asarray(ops.ip_count(jnp.asarray(db), jnp.asarray(qb),
                                  tile_q=8, tile_n=128, tile_v=128))
    want = np.asarray(ref.match_ip(jnp.asarray(db), jnp.asarray(qb)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("q,n,m", [(1, 5, 3), (3, 130, 17), (2, 90, 600)])
def test_tanimoto_count_sweep(q, n, m, rng):
    """Collision counts with the signature axis tiled through the grid
    (FLASH-scale m streams through VMEM)."""
    d = rng.integers(0, 64, size=(n, m)).astype(np.int32)
    s = rng.integers(0, 64, size=(q, m)).astype(np.int32)
    got = np.asarray(ops.tanimoto_count(jnp.asarray(d), jnp.asarray(s),
                                        tile_q=8, tile_n=128, tile_m=128))
    want = np.asarray(ref.match_tanimoto(jnp.asarray(d), jnp.asarray(s)))
    assert got.shape == (q, n)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("q,n,v", [(2, 90, 33), (4, 300, 256), (1, 40, 513)])
def test_cosine_count_sweep(q, n, v, rng):
    """Sign-agreement counts via the +-1 MXU matmul, odd V included (the
    shift by logical V must ignore zero padding)."""
    db = rng.choice(np.array([-1, 1], np.int8), size=(n, v))
    qb = rng.choice(np.array([-1, 1], np.int8), size=(q, v))
    got = np.asarray(ops.cosine_count(jnp.asarray(db), jnp.asarray(qb),
                                      tile_q=8, tile_n=128, tile_v=128))
    want = np.asarray(ref.match_cosine(jnp.asarray(db), jnp.asarray(qb)))
    assert np.array_equal(got, want)
    assert got.min() >= 0 and got.max() <= v


@pytest.mark.parametrize("q,n,mx", [(2, 100, 9), (4, 513, 31), (8, 64, 127)])
def test_cpq_hist_sweep(q, n, mx, rng):
    counts = rng.integers(0, mx + 1, size=(q, n)).astype(np.int32)
    got = np.asarray(ops.cpq_hist(jnp.asarray(counts), mx, tile_q=8, tile_n=128))
    want = np.asarray(ref.cpq_hist(jnp.asarray(counts), mx + 1))
    assert np.array_equal(got, want)
    assert got.sum(axis=1).max() <= n


def test_kernel_vs_engine_end_to_end(rng):
    """GenieIndex with kernels on == engines off produce identical results."""
    from repro.core import GenieIndex

    sigs = rng.integers(0, 16, size=(300, 24)).astype(np.int32)
    qs = rng.integers(0, 16, size=(5, 24)).astype(np.int32)
    a = GenieIndex.build_lsh(sigs, use_kernel=True).search(qs, k=7)
    b = GenieIndex.build_lsh(sigs, use_kernel=False).search(qs, k=7)
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert np.array_equal(np.asarray(a.threshold), np.asarray(b.threshold))
