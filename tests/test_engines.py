"""MatchModel registry round-trip: every engine through every search path.

The acceptance bar for the unified-engine refactor: all six engines (EQ,
RANGE, MINSUM, IP, TANIMOTO, COSINE) resolve through the registry with
kernel-vs-reference parity, the count-dtype policy is engine-uniform, and
multiload/distributed searches agree with single-device results.  The
exhaustive engine x path x match-impl sweep lives in test_engine_matrix.py.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GenieIndex, cpq, engines
from repro.core.types import Engine, SearchParams, TopKMethod

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _case(engine: Engine, rng, n=96, q=4):
    """(raw data, raw queries, max_count) for one engine -- the descriptor's
    own conformance generator (MatchModel.example), so there is exactly one
    per-engine data recipe in the system."""
    return engines.get(engine).example(rng, n, q)


ALL_ENGINES = [Engine.EQ, Engine.RANGE, Engine.MINSUM, Engine.IP,
               Engine.TANIMOTO, Engine.COSINE]


def test_all_engines_registered():
    assert set(engines.available()) >= set(ALL_ENGINES)
    for eng in ALL_ENGINES:
        model = engines.get(eng)
        assert model.engine == eng
        assert engines.get(eng.value) is model          # string lookup
        assert engines.get(model) is model              # idempotent


def test_unknown_engine_raises():
    with pytest.raises(ValueError):
        engines.get("no-such-engine")


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_kernel_matches_reference(engine, rng):
    data, queries, mc = _case(engine, rng)
    model = engines.get(engine)
    ref = np.asarray(model.match_counts(model.prepare_data(data), queries, use_kernel=False))
    ker = np.asarray(model.match_counts(model.prepare_data(data), queries, use_kernel=True))
    assert np.array_equal(ref, ker)


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_generic_build_equals_named_builder(engine, rng):
    data, queries, mc = _case(engine, rng)
    generic = GenieIndex.build(engine, data, max_count=mc, use_kernel=False)
    named = {
        Engine.EQ: lambda: GenieIndex.build_lsh(data, use_kernel=False),
        Engine.RANGE: lambda: GenieIndex.build_relational(data, use_kernel=False),
        Engine.MINSUM: lambda: GenieIndex.build_minsum(data, max_count=mc, use_kernel=False),
        Engine.IP: lambda: GenieIndex.build_ip(data, max_count=mc, use_kernel=False),
        Engine.TANIMOTO: lambda: GenieIndex.build_tanimoto(data, use_kernel=False),
        Engine.COSINE: lambda: GenieIndex.build_cosine(data, use_kernel=False),
    }[engine]()
    assert named.engine == generic.engine == engine
    assert named.max_count == generic.max_count
    assert named.stats.n_objects == generic.stats.n_objects
    assert named.stats.total_postings == generic.stats.total_postings
    a = generic.search(queries, k=7)
    b = named.search(queries, k=7)
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))


def test_build_requires_max_count_when_underivable(rng):
    data, _, _ = _case(Engine.MINSUM, rng)
    with pytest.raises(ValueError, match="max_count"):
        GenieIndex.build(Engine.MINSUM, data)


def test_count_dtype_policy():
    model = engines.get(Engine.EQ)
    assert model.count_dtype(100) == jnp.int8
    assert model.count_dtype(1000) == jnp.int16
    assert model.count_dtype(10**6) == jnp.int32


@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("method", [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT])
def test_search_methods_agree_per_engine(engine, method, rng):
    data, queries, mc = _case(engine, rng)
    idx = GenieIndex.build(engine, data, max_count=mc, use_kernel=False)
    got = idx.search(queries, k=9, method=method)
    want = cpq.sort_select(idx.match_counts(queries),
                           SearchParams(k=9, max_count=idx.max_count))
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts))


@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("n_parts", [1, 3, 5])
def test_multiload_parity_all_engines(engine, n_parts, rng):
    """Every registered engine streams through multiload, uneven splits
    included (pad rows are engine-neutral and masked)."""
    data, queries, mc = _case(engine, rng, n=97)   # uneven on purpose
    idx = GenieIndex.build(engine, data, max_count=mc, use_kernel=False)
    full = idx.search(queries, k=6)
    part = idx.search_multiload(queries, k=6, n_parts=n_parts)
    assert np.array_equal(np.asarray(full.counts), np.asarray(part.counts)), engine


def test_distributed_parity_all_engines():
    """All four engines through the sharded search step (8 forced CPU devices
    via subprocess: jax locks the device count at first init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed, engines, cpq
        from repro.core.types import Engine, SearchParams
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        cases = {
            Engine.EQ: (rng.integers(0, 6, (128, 16)).astype(np.int32),
                        jnp.asarray(rng.integers(0, 6, (4, 16)).astype(np.int32)), 16),
            Engine.MINSUM: (rng.integers(0, 3, (128, 32)).astype(np.int32),
                            jnp.asarray(rng.integers(0, 3, (4, 32)).astype(np.int32)), 96),
            Engine.IP: (rng.integers(0, 2, (128, 32)).astype(np.int32),
                        jnp.asarray(rng.integers(0, 2, (4, 32)).astype(np.int32)), 32),
        }
        lo = rng.integers(0, 5, (4, 6)).astype(np.int32)
        cases[Engine.RANGE] = (rng.integers(0, 10, (128, 6)).astype(np.int32),
                               (jnp.asarray(lo), jnp.asarray(lo + 3)), 6)
        for eng, (data, queries, mx) in cases.items():
            params = SearchParams(k=7, max_count=mx)
            step = distributed.make_search_step(mesh, params, eng)
            dd = jax.device_put(data, distributed.data_sharding(mesh))
            qq = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, distributed.replicated(mesh, 2)), queries)
            res = step(dd, qq)
            counts = engines.get(eng).match_fn(False)(jnp.asarray(data), queries)
            want = cpq.sort_select(counts, params)
            assert np.array_equal(np.asarray(res.counts), np.asarray(want.counts)), eng
        print('distributed registry parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "distributed registry parity OK" in out.stdout


def test_retrieval_service_search_before_add_raises(rng):
    """Regression: search() on an empty service raises ValueError (a bare
    assert would vanish under python -O) naming the service state."""
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    with pytest.raises(ValueError, match=r"RetrievalService.*empty.*add\(\)"):
        svc.search(None, k=1, embeddings=rng.standard_normal((2, 8)).astype(np.float32))


@pytest.mark.parametrize("scheme,engine", [("simhash", Engine.COSINE),
                                           ("minhash", Engine.TANIMOTO),
                                           ("e2lsh", Engine.EQ)])
def test_retrieval_service_scheme_selects_engine(scheme, engine, rng):
    """Selecting an LSH scheme by name selects its paired match engine and
    similarity MLE end-to-end."""
    from repro.serve.retrieval import RetrievalService

    pts = rng.standard_normal((150, 16)).astype(np.float32)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), scheme=scheme,
                           m_override=128)
    svc.add(list(range(150)), embeddings=pts)
    assert svc._index.engine == engine
    res, sims = svc.search(None, k=3, embeddings=pts[40:45] + 0.01)
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(40, 45))
    assert sims.shape == (5, 3)
    # self-similarity estimate must top each row and stay in the measure range
    assert np.all(sims[:, 0] + 1e-9 >= sims[:, 1:].max(axis=-1))
    assert sims.min() >= -1.0 and sims.max() <= 1.0


def test_retrieval_service_incremental_add(rng):
    """add() appends to the corpus instead of clobbering earlier adds."""
    from repro.serve.retrieval import RetrievalService

    pts = rng.standard_normal((120, 16)).astype(np.float32)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=96)
    svc.add(list(range(60)), embeddings=pts[:60])
    svc.add(list(range(60, 120)), embeddings=pts[60:])
    assert len(svc) == 120
    res, _ = svc.search(None, k=1, embeddings=pts[90:95] + 0.01)
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(90, 95))


def test_lsh_scheme_registry():
    from repro.core import lsh

    assert set(lsh.scheme_names()) >= {"e2lsh", "rbh", "simhash", "minhash"}
    scheme = lsh.get_scheme("e2lsh")
    assert lsh.get_scheme(scheme) is scheme
    with pytest.raises(KeyError):
        lsh.get_scheme("no-such-scheme")
    # scheme -> engine pairing used by serving
    assert lsh.get_scheme("simhash").engine == Engine.COSINE
    assert lsh.get_scheme("minhash").engine == Engine.TANIMOTO
    assert lsh.get_scheme("e2lsh").engine == Engine.EQ


def test_minhash_estimate_tracks_exact_tanimoto(rng):
    """The TANIMOTO engine's collision counts converge to the exact
    sum-min/sum-max oracle (binary multisets -> set Jaccard)."""
    import jax

    from repro.core import lsh as lsh_lib
    from repro.core.match import tanimoto_exact

    vecs = (rng.random((12, 64)) < 0.4).astype(np.float32)     # binary multisets
    scheme = lsh_lib.get_scheme("minhash")
    params = scheme.make_params(jax.random.PRNGKey(0), d=64, m=2000,
                                n_buckets=1 << 20)
    sigs = scheme.hash_points(params, jnp.asarray(vecs))
    model = engines.get(Engine.TANIMOTO)
    counts = np.asarray(model.match_counts(sigs, sigs, use_kernel=False))
    est = counts / 2000.0
    exact = np.asarray(tanimoto_exact(jnp.asarray(vecs, dtype=jnp.int32),
                                      jnp.asarray(vecs, dtype=jnp.int32)))
    assert np.allclose(np.diag(exact), 1.0)
    assert np.abs(est - exact).max() < 0.05


def test_simhash_mle_cosine_inverts_counts(rng):
    """cos_hat = cos(pi(1 - c/m)) recovers the true cosine from COSINE-engine
    counts on simhash bits."""
    import jax

    from repro.core import lsh as lsh_lib
    from repro.core.lsh import simhash

    x = rng.standard_normal((8, 16)).astype(np.float32)
    scheme = lsh_lib.get_scheme("simhash")
    params = scheme.make_params(jax.random.PRNGKey(1), d=16, m=4000)
    sigs = scheme.hash_points(params, jnp.asarray(x))
    model = engines.get(Engine.COSINE)
    counts = np.asarray(model.match_counts(model.prepare_data(sigs), sigs,
                                           use_kernel=False))
    est = simhash.mle_cosine(counts, 4000)
    xn = x / np.linalg.norm(x, axis=-1, keepdims=True)
    true = xn @ xn.T
    assert np.abs(est - true).max() < 0.08
