"""Serving engine: batched prefill+decode generation."""
import jax
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.registry import get_api, get_config
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b-smoke", "mamba2-1.3b-smoke"])
def test_generate_batch(arch):
    cfg = get_config(arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, api, params, cache_cap=64)
    batch = SyntheticTokens(cfg, DataConfig(global_batch=3, seq_len=16)).batch(0)
    toks, stats = eng.generate(batch, max_new_tokens=8)
    assert toks.shape == (3, 8)
    assert np.all(toks >= 0) and np.all(toks < cfg.vocab)
    assert stats.tokens_generated == 24
    # greedy decoding is deterministic
    toks2, _ = eng.generate(batch, max_new_tokens=8)
    assert np.array_equal(toks, toks2)


def test_generate_zero_new_tokens():
    """Regression: max_new_tokens=0 used to IndexError on outs[0]; it must
    return an empty [B, 0] batch with zeroed stats (and no device work)."""
    cfg = get_config("phi3-mini-3.8b-smoke")
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, api, params, cache_cap=64)
    batch = SyntheticTokens(cfg, DataConfig(global_batch=3, seq_len=16)).batch(0)
    toks, stats = eng.generate(batch, max_new_tokens=0)
    assert toks.shape == (3, 0)
    assert toks.dtype == np.int32
    assert stats.tokens_generated == 0
    assert stats.prefill_seconds == 0.0 and stats.decode_seconds == 0.0
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.generate(batch, max_new_tokens=-1)


def test_generate_sampled_differs_by_seed():
    cfg = get_config("phi3-mini-3.8b-smoke")
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, api, params, cache_cap=64)
    batch = SyntheticTokens(cfg, DataConfig(global_batch=2, seq_len=16)).batch(0)
    a, _ = eng.generate(batch, max_new_tokens=12, greedy=False, temperature=2.0, seed=0)
    b, _ = eng.generate(batch, max_new_tokens=12, greedy=False, temperature=2.0, seed=1)
    assert not np.array_equal(a, b)
