"""MoE dispatch correctness: the capacity-based gather/scatter dispatch must
equal a dense per-token reference when nothing is dropped, and drop
deterministically in slot order when capacity binds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(arch_id="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=2, d_ff=0, vocab=32, n_experts=4, experts_top_k=2,
                moe_d_ff=24, shared_expert_d_ff=0, capacity_factor=64.0)
    base.update(kw)
    return ModelConfig(**base)


def _dense_reference(x, p, cfg):
    """y[t] = sum_k w_k * SwiGLU_{e_k}(x_t), computed per token (no capacity)."""
    b, s, d = x.shape
    xf = np.asarray(x, np.float32).reshape(-1, d)
    logits = xf @ np.asarray(p["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.experts_top_k)
    top_p = np.asarray(top_p / jnp.sum(top_p, axis=-1, keepdims=True))
    top_e = np.asarray(top_e)
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)
    y = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        for k in range(cfg.experts_top_k):
            e = top_e[t, k]
            gate = xf[t] @ wg[e]
            up = xf[t] @ wu[e]
            act = gate / (1 + np.exp(-gate)) * up
            y[t] += top_p[t, k] * (act @ wd[e])
    return y.reshape(b, s, d)


def test_moe_matches_dense_reference(rng):
    cfg = _cfg()
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32, 1.0)
    x = jnp.asarray(rng.standard_normal((2, 6, cfg.d_model)), jnp.float32)
    y, aux = M.moe_ffn(x, p, cfg)
    want = _dense_reference(x, p, cfg)
    assert np.abs(np.asarray(y) - want).max() < 1e-4
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_monotone(rng):
    """Lower capacity only ever zeroes contributions (never invents them)."""
    x = jnp.asarray(rng.standard_normal((1, 16, 16)), jnp.float32)
    cfg_hi = _cfg(capacity_factor=64.0)
    p = M.init_moe(jax.random.PRNGKey(0), cfg_hi, jnp.float32, 1.0)
    y_hi, _ = M.moe_ffn(x, p, cfg_hi)
    cfg_lo = _cfg(capacity_factor=0.5)
    y_lo, _ = M.moe_ffn(x, p, cfg_lo)
    # tokens served in the low-capacity run match the high-capacity output;
    # dropped slots contribute zero, so |y_lo| <= |y_hi| + matched entries agree
    diff_tokens = np.abs(np.asarray(y_hi - y_lo)).max(axis=-1)[0]
    served = diff_tokens < 1e-5
    assert served.sum() >= 1                       # somebody fits in capacity
    assert (~served).sum() >= 1                    # and somebody was dropped
    assert M.capacity(16, cfg_lo) < M.capacity(16, cfg_hi)


def test_moe_shared_expert_gating(rng):
    cfg = _cfg(shared_expert_d_ff=32)
    p = M.init_moe(jax.random.PRNGKey(1), cfg, jnp.float32, 1.0)
    x = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)), jnp.float32)
    y, _ = M.moe_ffn(x, p, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
