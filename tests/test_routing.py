"""Coarse-routing conformance suite (core/routing.py + the routed executors
in core/plan.py).

The load-bearing guarantee: ROUTED_VERIFIED is bit-for-bit identical to the
full scan -- identical ids, counts, AND thresholds -- across

    6 engines x {CPQ, SPQ, SORT} x {SEGMENTED, MULTILOAD host loop,
    DISTRIBUTED (subprocess, 8 forced CPU devices)}

because the router's per-engine scores are true *upper bounds* on any row's
match count, and the verified mode falls back to the full scan whenever a
skipped segment's bound reaches the routed threshold (`>=`: a tied count
with a smaller id displaces the k-th slot).  The suite also pins:

  * upper-bound soundness per engine (UB >= the real per-segment max count),
    through merge_summaries (compaction) as well;
  * that routed searches genuinely skip device work for cold segments (no
    part kernel traced for a pruned row count) and genuinely fall back when
    a skipped bound ties the threshold;
  * plan-level plumbing: routing rejected on the single-program layouts,
    routing/nprobe in describe() and in the plan cache key, router=
    validation at execute();
  * RetrievalService routing: parity, router-cache invalidation on add;
  * PR-7 satellites: iterator queries to search(), candidate_cap threading,
    describe() truncation consistency, the empty-corpus items_for message,
    monotonic build/compaction clocks, dead merge._offset_ids removal.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import GenieIndex, SegmentedIndex, cpq, engines
from repro.core import plan as plan_lib
from repro.core import routing as routing_lib
from repro.core.types import Engine, SearchParams, TopKMethod, TopKResult

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ALL_ENGINES = sorted(engines.available(), key=lambda e: e.value)
ALL_METHODS = [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT]

# uneven on purpose (mirrors test_plan.py): a 1-row segment, a segment
# smaller than k, a big one -- routing must stay exact on ragged parts
CUTS = [0, 3, 4, 40, 90, 101]


def _case(engine: Engine, n=101, q=4, seed=0):
    model = engines.get(engine)
    raw, queries, mc = model.example(np.random.default_rng(seed), n, q)
    data = model.prepare_data(raw)
    return model, raw, data, queries, model.resolve_max_count(data, mc)


def _segmented(engine: Engine, raw, mc) -> SegmentedIndex:
    seg = SegmentedIndex(engine=engine, max_count=mc, use_kernel=False)
    for a, b in zip(CUTS, CUTS[1:]):
        seg.add(raw[a:b])
    return seg


def _assert_same(got, want, label=""):
    assert np.array_equal(np.asarray(got.ids), np.asarray(want.ids)), label
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts)), label
    assert np.array_equal(np.asarray(got.threshold),
                          np.asarray(want.threshold)), label


# ---------------------------------------------------------------------------
# Conformance: ROUTED_VERIFIED == full scan, engine x method x host layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("method", ALL_METHODS)
def test_routed_verified_equals_full_scan(engine, method):
    """ROUTED_VERIFIED at the most aggressive pruning (nprobe=1) reproduces
    the full scan bit-for-bit on both host-loop layouts, and ROUTED with
    every probe open is trivially the full scan too."""
    k = 9
    model, raw, data, queries, mc = _case(engine)
    seg = _segmented(engine, raw, mc)
    n_seg = len(seg.segments)
    for name, search in (("segmented", seg.search),
                         ("multiload-host", seg.search_multiload)):
        full = search(queries, k, method=method)
        verified = search(queries, k, method=method,
                          routing="routed_verified", nprobe=1)
        _assert_same(verified, full,
                     f"{engine.value} {method.value} {name} verified")
        wide_open = search(queries, k, method=method,
                           routing="routed", nprobe=n_seg)
        _assert_same(wide_open, full,
                     f"{engine.value} {method.value} {name} all-probes")


# ---------------------------------------------------------------------------
# Upper-bound soundness: the router's whole contract, per engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_upper_bound_is_sound_per_segment(engine):
    """For every engine, segment, and query: upper_bound(summary, q) >= the
    true max match count any of the segment's rows reaches (the reference
    count matrix is the oracle).  This is the property ROUTED_VERIFIED's
    exactness rests on."""
    model, raw, data, queries, mc = _case(engine, q=6, seed=3)
    prepared_q = model.prepare_queries(queries)
    counts = np.asarray(model.reference(data, prepared_q))  # [Q, N]
    wide = np.asarray(data)
    for a, b in zip(CUTS, CUTS[1:]):
        summ = routing_lib.summarize(engine, wide[a:b])
        ub = routing_lib.upper_bound(summ, prepared_q)
        actual = counts[:, a:b].max(axis=1)
        assert (ub >= actual - 1e-9).all(), \
            f"{engine.value} segment [{a}:{b}]: UB {ub} < actual {actual}"


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_merged_summary_stays_sound(engine):
    """merge_summaries (what compaction aggregates) still upper-bounds the
    concatenated segment, and merges bookkeeping row-weighted."""
    model, raw, data, queries, mc = _case(engine, n=90, q=5, seed=7)
    prepared_q = model.prepare_queries(queries)
    counts = np.asarray(model.reference(data, prepared_q))
    wide = np.asarray(data)
    a = routing_lib.summarize(engine, wide[:40])
    b = routing_lib.summarize(engine, wide[40:])
    merged = routing_lib.merge_summaries(a, b)
    assert merged.n_rows == 90
    assert np.allclose(merged.centroid,
                       (a.centroid * 40 + b.centroid * 50) / 90)
    ub = routing_lib.upper_bound(merged, prepared_q)
    assert (ub >= counts.max(axis=1) - 1e-9).all(), \
        f"{engine.value}: merged UB {ub} < actual {counts.max(axis=1)}"


@pytest.mark.parametrize("engine", [Engine.EQ, Engine.COSINE, Engine.RANGE])
def test_compaction_merges_summaries_and_keeps_parity(engine):
    """compact() carries routing through: merged segments keep (merged)
    summaries and ROUTED_VERIFIED stays bit-for-bit after compaction."""
    model, raw, data, queries, mc = _case(engine)
    seg = _segmented(engine, raw, mc)
    full = seg.search(queries, 9)
    seg.compact(2)
    assert len(seg.segments) == 2
    assert all(s.summary is not None for s in seg.segments), \
        "compaction dropped a routing summary"
    verified = seg.search(queries, 9, routing="routed_verified", nprobe=1)
    _assert_same(verified, full, f"{engine.value} post-compaction")


# ---------------------------------------------------------------------------
# The router actually skips -- and actually falls back
# ---------------------------------------------------------------------------

def test_routed_skips_cold_segment_without_device_work():
    """A segment the router rules out (UB strictly under the threshold) is
    never traced: no per-part kernel exists for its row count.  Two EQ
    segments with disjoint bucket values make the pruning deterministic."""
    cold = np.zeros((40, 16), dtype=np.int32)
    hot = np.full((35, 16), 7, dtype=np.int32)
    seg = SegmentedIndex(engine=Engine.EQ, use_kernel=False)
    seg.add(cold)
    seg.add(hot)
    q = np.full((2, 16), 7, dtype=np.int32)
    plan_lib.clear_plan_cache()
    verified = seg.search(q, 5, routing="routed_verified", nprobe=1)
    traced_rows = {key[-1] for key in plan_lib._TRACE_COUNTS
                   if key[0] == "part"}
    assert 35 in traced_rows, "the routed segment was not scanned"
    assert 40 not in traced_rows, \
        "the pruned segment was traced -- routing did no device-work pruning"
    _assert_same(verified, seg.search(q, 5), "cold-segment skip")


def test_verified_falls_back_on_tied_upper_bound():
    """When a skipped segment's bound TIES the routed threshold the verified
    mode must rescan (a tied count with a smaller id displaces the k-th
    slot): identical segments force the tie, and both row counts trace."""
    seg = SegmentedIndex(engine=Engine.EQ, use_kernel=False)
    seg.add(np.full((40, 16), 7, dtype=np.int32))
    seg.add(np.full((35, 16), 7, dtype=np.int32))
    q = np.full((2, 16), 7, dtype=np.int32)
    plan_lib.clear_plan_cache()
    verified = seg.search(q, 5, routing="routed_verified", nprobe=1)
    traced_rows = {key[-1] for key in plan_lib._TRACE_COUNTS
                   if key[0] == "part"}
    assert {35, 40} <= traced_rows, \
        f"tied upper bound must force the full-scan fallback, traced {traced_rows}"
    _assert_same(verified, seg.search(q, 5), "tied-bound fallback")


def test_unfilled_topk_slot_forces_fallback():
    """threshold == -1 (an unfilled k-th slot) must always trigger the
    fallback: any sound bound (>= 0) reaches it.  Strictly smaller bounds
    must not."""
    two = np.full((1, 2), -1, dtype=np.int32)
    res = TopKResult(ids=two, counts=two, threshold=np.array([-1]))
    verify = np.array([False, True])
    assert plan_lib._skipped_could_contribute(res, np.zeros((1, 2)), verify)
    res3 = TopKResult(ids=two, counts=two, threshold=np.array([3]))
    assert not plan_lib._skipped_could_contribute(
        res3, np.array([[9.0, 2.0]]), verify)
    assert plan_lib._skipped_could_contribute(
        res3, np.array([[0.0, 3.0]]), verify), \
        "UB == threshold must fall back (tie displaces the k-th slot)"


# ---------------------------------------------------------------------------
# Plan plumbing: validation, describe(), cache key, execute() contracts
# ---------------------------------------------------------------------------

def test_plan_rejects_routing_on_single_program_layouts():
    with pytest.raises(ValueError, match="nothing to skip"):
        plan_lib.plan_search(Engine.EQ, 5, 16, routing="routed")
    with pytest.raises(ValueError, match="nothing to skip"):
        plan_lib.plan_search(Engine.EQ, 5, 16,
                             layout=plan_lib.Layout.MULTILOAD,
                             n_parts=4, n_objects=101, routing="routed")
    with pytest.raises(ValueError, match="nprobe"):
        plan_lib.plan_search(Engine.EQ, 5, 16,
                             layout=plan_lib.Layout.SEGMENTED,
                             part_rows=(3, 4), routing="routed", nprobe=0)


def test_plan_routing_in_describe_and_cache_key():
    common = dict(layout=plan_lib.Layout.SEGMENTED, part_rows=(3, 4),
                  use_kernel=False)
    full = plan_lib.plan_search(Engine.EQ, 5, 16, **common)
    routed = plan_lib.plan_search(Engine.EQ, 5, 16, routing="routed_verified",
                                  nprobe=2, **common)
    assert full != routed and hash(full) != hash(routed), \
        "routed and full plans must be distinct executor-cache keys"
    d = routed.describe()
    assert d["routing"] == "routed_verified" and d["nprobe"] == 2
    assert full.describe()["routing"] == "none"
    # a full-scan plan ignores nprobe so its cache key stays canonical
    assert plan_lib.plan_search(Engine.EQ, 5, 16, nprobe=7, **common,
                                ).nprobe is None


def test_routed_plans_share_part_kernels_with_full_scans():
    """The per-part kernel cache key deliberately excludes routing: a routed
    plan and its full-scan twin compile the same part programs once."""
    common = dict(layout=plan_lib.Layout.SEGMENTED, part_rows=(3, 4),
                  use_kernel=False)
    full = plan_lib.plan_search(Engine.EQ, 5, 16, **common)
    routed = plan_lib.plan_search(Engine.EQ, 5, 16, routing="routed",
                                  **common)
    for rows in (3, 4):
        assert plan_lib._part_key(full, rows) == plan_lib._part_key(routed, rows)


def test_execute_validates_router():
    model, raw, data, queries, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)
    plan = plan_lib.plan_search(
        Engine.EQ, 5, mc, layout=plan_lib.Layout.SEGMENTED,
        part_rows=tuple(seg.segment_rows), use_kernel=False, routing="routed")
    parts = [s.data for s in seg.segments]
    q = model.prepare_queries(queries)
    with pytest.raises(ValueError, match="router="):
        plan_lib.execute(plan, parts, q)
    stale = SegmentedIndex(engine=Engine.EQ, max_count=mc, use_kernel=False)
    stale.add(raw[:50])
    stale.add(raw[50:])
    with pytest.raises(ValueError, match="rebuild the router"):
        plan_lib.execute(plan, parts, q, router=stale.router())


def test_router_and_summary_validation():
    with pytest.raises(ValueError, match="at least one"):
        routing_lib.Router(engine=Engine.EQ, summaries=[])
    with pytest.raises(ValueError, match="non-empty"):
        routing_lib.summarize(Engine.EQ, np.zeros((0, 4), dtype=np.int32))
    with pytest.raises(ValueError, match="non-empty"):
        routing_lib.summarize(Engine.EQ, np.zeros(4, dtype=np.int32))
    a = routing_lib.summarize(Engine.EQ, np.zeros((3, 4), dtype=np.int32))
    b = routing_lib.summarize(Engine.COSINE, np.ones((3, 4), dtype=np.int8))
    with pytest.raises(ValueError, match="engines"):
        routing_lib.merge_summaries(a, b)
    wide = routing_lib.summarize(Engine.EQ, np.zeros((3, 6), dtype=np.int32))
    with pytest.raises(ValueError, match="widths"):
        routing_lib.merge_summaries(a, wide)
    with pytest.raises(ValueError, match="add\\(\\) first"):
        SegmentedIndex(engine=Engine.EQ).router()
    # a hand-assembled segment without a seal-time summary is named
    model, raw, data, queries, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)
    seg.segments[0] = dataclasses.replace(seg.segments[0], summary=None)
    with pytest.raises(ValueError, match="segments \\[0\\]"):
        seg.router()


# ---------------------------------------------------------------------------
# RetrievalService routing (single device; the mesh leg runs in a subprocess)
# ---------------------------------------------------------------------------

def _clustered_service(rng, mesh=None, n_clusters=5, per_cluster=30, d=12):
    from repro.serve.retrieval import RetrievalService

    centers = rng.standard_normal((n_clusters, d)).astype(np.float32)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), scheme="simhash",
                           m_override=64, mesh=mesh)
    for c in range(n_clusters):
        pts = (centers[c] + 0.1 * rng.standard_normal(
            (per_cluster, d))).astype(np.float32)
        svc.add([f"c{c}-{i}" for i in range(per_cluster)], embeddings=pts)
    return svc, centers


def test_service_routing_parity_and_router_cache():
    rng = np.random.default_rng(0)
    svc, centers = _clustered_service(rng)
    qe = (centers[:2] + 0.05 * rng.standard_normal(
        centers[:2].shape)).astype(np.float32)
    full, sims_full = svc.search(None, k=5, embeddings=qe)
    verified, sims_ver = svc.search(None, k=5, embeddings=qe,
                                    routing="routed_verified", nprobe=1)
    _assert_same(verified, full, "service routed_verified")
    assert np.allclose(sims_ver, sims_full)
    # router cached until the corpus fingerprint changes
    router = svc._router()
    assert svc._router() is router, "router not cached across searches"
    svc.add(["late"], embeddings=centers[:1])
    assert svc._router() is not router, "router not invalidated by add()"
    refreshed, _ = svc.search(None, k=5, embeddings=qe,
                              routing="routed_verified", nprobe=1)
    _assert_same(refreshed, svc.search(None, k=5, embeddings=qe)[0],
                 "service routed_verified after corpus growth")


# ---------------------------------------------------------------------------
# Satellites
# ---------------------------------------------------------------------------

def test_service_search_accepts_iterator_queries():
    """search(queries) must materialise iterators/generators before len()
    (the add() contract) instead of crashing on a generator."""
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(
        embed_fn=lambda items: np.asarray(
            [[float(i), float(i) + 1.0] for i in items], dtype=np.float32),
        scheme="simhash", m_override=32)
    svc.add(range(8))
    from_list, _ = svc.search([2, 3], k=3)
    from_gen, _ = svc.search((i for i in [2, 3]), k=3)
    _assert_same(from_gen, from_list, "generator queries")
    from_iter, _ = svc.search(iter([2, 3]), k=3)
    _assert_same(from_iter, from_list, "iterator queries")


def test_candidate_cap_threads_through_host_loops_and_service(monkeypatch):
    """candidate_cap must reach the CPQ candidate buffer on every entry point
    that forwards it: SegmentedIndex.search_multiload, the scanned
    GenieIndex.search_multiload, and RetrievalService.search.  The observable
    is the cap the compaction kernel is traced with: max(candidate_cap, k),
    or the max(2k, k+16) default when unset."""
    seen = []
    orig = cpq._compact_candidates

    def spy(counts, threshold, cap):
        seen.append(int(cap))
        return orig(counts, threshold, cap)

    monkeypatch.setattr(cpq, "_compact_candidates", spy)
    model, raw, data, queries, mc = _case(Engine.EQ)
    seg = _segmented(Engine.EQ, raw, mc)

    plan_lib.clear_plan_cache()
    seg.search_multiload(queries, 5, candidate_cap=31)
    assert 31 in seen, f"multiload-host dropped candidate_cap: {seen}"

    seen.clear()
    plan_lib.clear_plan_cache()
    idx = GenieIndex.build(Engine.EQ, raw, max_count=mc, use_kernel=False)
    idx.search_multiload(queries, 5, n_parts=4, candidate_cap=29)
    assert 29 in seen, f"scanned multiload dropped candidate_cap: {seen}"

    seen.clear()
    plan_lib.clear_plan_cache()
    rng = np.random.default_rng(1)
    svc, centers = _clustered_service(rng, n_clusters=3, per_cluster=20)
    svc._index.use_kernel = False  # keep the spy on the reference CPQ path
    svc.search(None, k=5, embeddings=centers[:1], candidate_cap=27)
    assert 27 in seen, f"RetrievalService.search dropped candidate_cap: {seen}"

    seen.clear()
    plan_lib.clear_plan_cache()
    seg.search_multiload(queries, 5)
    assert 21 in seen, f"default cap should be max(2k, k+16)=21: {seen}"


def test_describe_truncation_is_consistent():
    """A >32-part plan truncates part_rows AND part_k the same way: both
     33 entries long, both ending in the explicit '...' marker (part_k used
    to truncate silently)."""
    big = plan_lib.plan_search(Engine.EQ, 2, 16,
                               layout=plan_lib.Layout.SEGMENTED,
                               part_rows=(3,) * 40, use_kernel=False)
    d = big.describe()
    assert len(d["part_rows"]) == 33 and d["part_rows"][-1] == "..."
    assert len(d["part_k"]) == 33 and d["part_k"][-1] == "..."
    assert d["part_rows"][:32] == [3] * 32 and d["part_k"][:32] == [2] * 32
    small = plan_lib.plan_search(Engine.EQ, 2, 16,
                                 layout=plan_lib.Layout.SEGMENTED,
                                 part_rows=(3,) * 4, use_kernel=False)
    ds = small.describe()
    assert ds["part_rows"] == [3] * 4 and ds["part_k"] == [2] * 4


def test_items_for_empty_corpus_message():
    """items_for on an empty corpus must not print the non-range '0..-1'."""
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    with pytest.raises(ValueError, match="no ids are valid"):
        svc.items_for(np.asarray([[0]]))
    svc.add([10, 11], embeddings=np.eye(2, dtype=np.float32))
    with pytest.raises(ValueError, match=r"valid ids are 0\.\.1"):
        svc.items_for(np.asarray([[5]]))


def test_build_and_compaction_clocks_are_monotonic():
    """Durations recorded by index build / compaction / postings must come
    from the monotonic clock -- a wall-clock (NTP) step must never record a
    negative duration."""
    import inspect

    from repro.core import index as index_mod
    from repro.core import postings as postings_mod
    from repro.core import segments as segments_mod

    for mod in (index_mod, segments_mod, postings_mod):
        src = inspect.getsource(mod)
        assert "time.time()" not in src, \
            f"{mod.__name__} times durations with the wall clock"
        assert "perf_counter" in src


def test_merge_dead_offset_helper_removed():
    from repro.core import merge as merge_mod

    assert not hasattr(merge_mod, "_offset_ids"), \
        "dead merge._offset_ids resurfaced"


# ---------------------------------------------------------------------------
# DISTRIBUTED routing (subprocess: 8 forced CPU devices)
# ---------------------------------------------------------------------------

def test_distributed_routing_parity():
    """ROUTED_VERIFIED at nprobe=1 on the DISTRIBUTED layout (shard masking
    + all-ones-mask fallback) equals the sort oracle bit-for-bit for every
    engine x method; ROUTED with every probe open is the full scan too."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.core import SegmentedIndex, cpq, distributed, engines
        from repro.core import plan as plan_lib
        from repro.core.types import Engine, SearchParams, TopKMethod
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        CUTS = [0, 3, 4, 40, 90, 101]
        for eng in sorted(engines.available(), key=lambda e: e.value):
            model = engines.get(eng)
            raw, rawq, mc = model.example(np.random.default_rng(0), 101, 4)
            seg = SegmentedIndex(engine=eng, max_count=mc, use_kernel=False)
            for a, b in zip(CUTS, CUTS[1:]):
                seg.add(raw[a:b])
            data, n = seg.concat_data(pad_multiple=mesh.size)
            queries = model.prepare_queries(rawq)
            mx = seg.max_count
            want = cpq.sort_select(
                model.reference(model.prepare_data(raw), queries),
                SearchParams(k=7, max_count=mx))
            dd = jax.device_put(data, distributed.data_sharding(mesh))
            qq = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, distributed.replicated(mesh, 2)),
                queries)
            router = seg.router()
            for method in TopKMethod:
                modes = [('routed_verified', 1)]
                # one wide-open ROUTED leg pins the no-fallback early return
                # without doubling the (engine x method) compile matrix
                if method is TopKMethod.CPQ and eng is Engine.EQ:
                    modes.append(('routed', len(CUTS) - 1))
                for mode, npb in modes:
                    plan = plan_lib.plan_search(
                        eng, 7, mx, layout=plan_lib.Layout.DISTRIBUTED,
                        n_objects=n, method=method, use_kernel=False,
                        mesh_axes=tuple(mesh.axis_names),
                        routing=mode, nprobe=npb)
                    res = plan_lib.execute(plan, dd, qq, mesh=mesh,
                                           router=router,
                                           route_queries=queries)
                    label = (eng.value, method.value, mode)
                    assert np.array_equal(np.asarray(res.ids),
                                          np.asarray(want.ids)), label
                    assert np.array_equal(np.asarray(res.counts),
                                          np.asarray(want.counts)), label
        print('distributed routing parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "distributed routing parity OK" in out.stdout


def test_distributed_service_routing_parity():
    """RetrievalService(mesh=...) with routing: identical to its own full
    scan AND to the single-device service, candidate_cap reaches the sharded
    CPQ buffers, and the router cache refreshes when the corpus changes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np
        from repro.core import cpq as cpq_lib
        from repro.core import plan as plan_lib
        from repro.launch import mesh as mesh_lib
        from repro.serve.retrieval import RetrievalService

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        centers = rng.standard_normal((6, 16)).astype(np.float32)

        def mk(m):
            return RetrievalService(embed_fn=lambda x: np.asarray(x),
                                    scheme='simhash', m_override=64, mesh=m)

        sharded, single = mk(mesh), mk(None)
        base = 0
        for c in range(6):
            pts = (centers[c] + 0.1 * rng.standard_normal(
                (40, 16))).astype(np.float32)
            ids = list(range(base, base + 40)); base += 40
            sharded.add(ids, embeddings=pts)
            single.add(ids, embeddings=pts)
        q = (np.repeat(centers[:3], 2, axis=0)
             + 0.05 * rng.standard_normal((6, 16))).astype(np.float32)
        full, _ = sharded.search(None, k=5, embeddings=q)

        seen = []
        orig = cpq_lib._compact_candidates
        def spy(counts, threshold, cap):
            seen.append(int(cap))
            return orig(counts, threshold, cap)
        cpq_lib._compact_candidates = spy
        plan_lib.clear_plan_cache()
        ver, _ = sharded.search(None, k=5, embeddings=q,
                                routing='routed_verified', candidate_cap=31)
        assert 31 in seen, seen
        assert np.array_equal(np.asarray(ver.ids), np.asarray(full.ids))
        assert np.array_equal(np.asarray(ver.counts), np.asarray(full.counts))
        ones, _ = single.search(None, k=5, embeddings=q,
                                routing='routed_verified')
        assert np.array_equal(np.asarray(ones.ids), np.asarray(ver.ids))

        router = sharded._router()
        assert sharded._router() is router, 'router not cached'
        sharded.add([999], embeddings=centers[:1])
        single.add([999], embeddings=centers[:1])
        assert sharded._router() is not router, 'router not refreshed'
        ver2, _ = sharded.search(None, k=5, embeddings=q,
                                 routing='routed_verified')
        full2, _ = single.search(None, k=5, embeddings=q)
        assert np.array_equal(np.asarray(ver2.ids), np.asarray(full2.ids))
        assert np.array_equal(np.asarray(ver2.counts),
                              np.asarray(full2.counts))
        print('distributed service routing OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "distributed service routing OK" in out.stdout
