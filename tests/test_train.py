"""Training substrate: loss descent, grad accumulation equivalence, AdamW,
gradient compression error feedback, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.registry import get_api, get_config
from repro.optim import adamw, compress, schedule
from repro.train import step as tsl


def _setup(arch="smollm-360m-smoke", **hp_kw):
    cfg = get_config(arch)
    api = get_api(cfg)
    hp = tsl.TrainHParams(optimizer=adamw.AdamWConfig(lr=2e-3), total_steps=50,
                          warmup_steps=5, **hp_kw)
    state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), hp)
    pipe = SyntheticTokens(cfg, DataConfig(global_batch=4, seq_len=64))
    return cfg, api, hp, state, pipe


def test_loss_decreases():
    cfg, api, hp, state, pipe = _setup()
    step = jax.jit(tsl.make_train_step(cfg, api, hp), donate_argnums=(0,))
    losses = []
    for i in range(30):
        state, m = step(state, pipe.batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_grad_accumulation_equivalent():
    """accum=2 over a batch == accum=1 over the same batch (same grads)."""
    cfg, api, _, _, pipe = _setup()
    batch = pipe.batch(0)
    hp1 = tsl.TrainHParams(accum=1, remat=False)
    hp2 = tsl.TrainHParams(accum=2, remat=False)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    g1 = jax.grad(lambda p: tsl.make_loss_fn(cfg, api, hp1)(p, batch)[0])(params)

    # manual accumulation over the two halves
    def half(i):
        hb = {k: v[i * 2 : (i + 1) * 2] for k, v in batch.items()}
        return jax.grad(lambda p: tsl.make_loss_fn(cfg, api, hp2)(p, hb)[0])(params)

    ga = jax.tree_util.tree_map(lambda a, b: (a + b) / 2, half(0), half(1))
    err = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(ga))
    )
    assert err < 5e-3, err


def test_adamw_against_reference():
    """One AdamW step == hand-computed reference on a tiny tree."""
    params = {"w": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([0.5])}
    grads = {"w": jnp.asarray([0.1, 0.2]), "b": jnp.asarray([-0.3])}
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                            clip_norm=1e9)
    st = adamw.init(params, cfg)
    new_p, st2, gnorm = adamw.update(grads, st, params, cfg)
    for k in params:
        g = np.asarray(grads[k], np.float64)
        m = 0.1 * g
        v = 0.01 * g * g
        mh = m / (1 - 0.9)
        vh = v / (1 - 0.99)
        want = np.asarray(params[k], np.float64) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
        assert np.abs(np.asarray(new_p[k]) - want).max() < 1e-5
    assert int(st2.step) == 1


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_compression_error_feedback(rng):
    """Dequantised grads + carried error == original grads (lossless in sum)."""
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    err = compress.init_error(g)
    total_true = np.zeros((64, 64))
    total_sent = np.zeros((64, 64))
    for i in range(8):
        gi = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
        total_true += np.asarray(gi["w"])
        deq, err = compress.apply(gi, err)
        total_sent += np.asarray(deq["w"])
    # error feedback: cumulative sent converges to cumulative true
    resid = np.abs(total_sent + np.asarray(err["w"]) - total_true).max()
    assert resid < 1e-3, resid


def test_cosine_schedule():
    lr0 = schedule.cosine_with_warmup(jnp.int32(0), peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr10 = schedule.cosine_with_warmup(jnp.int32(10), peak_lr=1.0, warmup_steps=10, total_steps=100)
    lr100 = schedule.cosine_with_warmup(jnp.int32(100), peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0
    assert abs(float(lr10) - 1.0) < 1e-5
    assert float(lr100) == pytest.approx(0.1, abs=1e-5)


def test_moe_aux_loss_decreases_imbalance():
    """The router aux loss is >= 1 (balanced == 1) and finite."""
    cfg = get_config("qwen2-moe-a2.7b-smoke")
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    pipe = SyntheticTokens(cfg, DataConfig(global_batch=2, seq_len=32))
    _, aux, _ = api.train_logits(cfg, params, pipe.batch(0), remat=False)
    assert float(aux) >= 0.99  # == n_experts * sum(me*ce) >= 1 by Cauchy-Schwarz
    assert np.isfinite(float(aux))
