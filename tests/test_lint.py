"""genielint suite: every rule catches its seeded violation, passes its
clean twin, the suppression syntax round-trips, and -- the gate the CI lane
enforces -- the repo at HEAD is finding-free.

Fixture files are laid out under a temp root that mirrors the production
tree (repro/core/..., repro/kernels/..., repro/serve/...), because rule
scoping keys on paths relative to the scan root: a kernel-contract fixture
only triggers if it lives under repro/kernels/.  The fixtures are parsed,
never imported -- the linter is pure-AST, so the snippets do not need a
working jax.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_SRC = os.path.join(_REPO, "src")
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)

from tools.genielint import LintConfig, run_lint  # noqa: E402
from tools.genielint.config import DEFAULT  # noqa: E402


def _tree(tmp_path, files: dict) -> str:
    """Write {relpath: source} under tmp_path; return the scan root."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src).strip("\n") + "\n")
    return str(tmp_path)


def _findings(root, rule, **cfg):
    config = LintConfig(**cfg) if cfg else DEFAULT
    return [f for f in run_lint(root, config=config, rules=[rule])
            if not f.suppressed]


# ---------------------------------------------------------------------------
# executor-sovereignty
# ---------------------------------------------------------------------------

def test_executor_sovereignty_fixture(tmp_path):
    root = _tree(tmp_path, {
        # violation: a legacy entry point re-deriving selection itself
        "repro/core/index.py": """
            from repro.core.select import select_topk

            def search(counts, k):
                ids, counts = select_topk(counts, k)   # line 4
                return merge_ragged(ids, counts)
        """,
        # clean twin: the executor family may call the governed helpers
        "repro/core/plan.py": """
            def execute(plan, counts):
                return select_topk(_mask_pad_counts(counts), plan.k)
        """,
        # clean: same call *names* in strings/docstrings never trip the rule
        "repro/core/docs.py": '''
            def helper():
                """Delegates instead of calling select_topk( directly."""
                return "merge_ragged("
        ''',
    })
    got = _findings(root, "executor-sovereignty")
    assert [(f.path, f.line) for f in got] == [
        ("repro/core/index.py", 4), ("repro/core/index.py", 5)]
    assert "executor family" in got[0].message


def test_executor_sovereignty_at_head():
    """The replacement for tests/test_plan.py's deleted string grep: no
    module outside the executor family calls the governed selection/merge/
    pad-mask helpers, anywhere under src/."""
    assert _findings(_SRC, "executor-sovereignty") == []


# ---------------------------------------------------------------------------
# pallas-kernel-contract
# ---------------------------------------------------------------------------

_KERNEL_HEADER = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
    "\n"
    "TILE = 128\n"
)


def _kernel_fixture(body: str) -> str:
    """Prepend the shared import header (5 lines) to a dedented body, so
    line numbers inside `body` start at 6."""
    return _KERNEL_HEADER + textwrap.dedent(body).strip("\n") + "\n"


def test_pallas_contract_fixture(tmp_path):
    root = _tree(tmp_path, {
        # violations: index-map arity 1 vs grid rank 2; float32 out dtype
        "repro/kernels/bad.py": _kernel_fixture("""
            def bad_count(q, d):
                grid = (4, 4)
                return pl.pallas_call(
                    _kernel,
                    grid=grid,
                    in_specs=[pl.BlockSpec((TILE, TILE), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
                    out_shape=jax.ShapeDtypeStruct((512, 512), jnp.float32),
                )(q.astype(jnp.int32))
        """),
        # violation: 2048x2048 f32 tile = 16 MiB > the 12 MiB budget
        "repro/kernels/fat.py": _kernel_fixture("""
            def fat_count(q):
                return pl.pallas_call(
                    _kernel,
                    grid=(1,),
                    in_specs=[pl.BlockSpec((2048, 2048), lambda i: (0, 0))],
                    out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
                    out_shape=jax.ShapeDtypeStruct((8, 8), jnp.int32),
                )(q.astype(jnp.float32))
        """),
        # clean twin: matched arity, int32 out, small tiles
        "repro/kernels/good.py": _kernel_fixture("""
            def good_count(q, d):
                grid = (4, 4)
                return pl.pallas_call(
                    _kernel,
                    grid=grid,
                    in_specs=[
                        pl.BlockSpec((TILE, TILE), lambda i, j: (i, 0)),
                        pl.BlockSpec((TILE, TILE), lambda i, j: (j, 0)),
                    ],
                    out_specs=pl.BlockSpec((TILE, TILE), lambda i, j: (i, j)),
                    out_shape=jax.ShapeDtypeStruct((512, 512), jnp.int32),
                )(q.astype(jnp.int32), d.astype(jnp.int32))
        """),
        # out of scope: same pallas_call outside repro/kernels/ is ignored
        "repro/core/not_a_kernel.py": _kernel_fixture("""
            def lookalike(q):
                return pl.pallas_call(
                    _kernel, grid=(1,),
                    out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float64),
                )(q)
        """),
    })
    got = _findings(root, "pallas-kernel-contract")
    by_file = {}
    for f in got:
        by_file.setdefault(f.path, []).append(f.message)
    assert sorted(by_file) == ["repro/kernels/bad.py", "repro/kernels/fat.py"]
    bad = "\n".join(by_file["repro/kernels/bad.py"])
    assert "takes 1 indices but the grid has rank 2" in bad
    assert "float32 violates the registry count policy" in bad
    fat = "\n".join(by_file["repro/kernels/fat.py"])
    assert "VMEM tile footprint" in fat and "16777472" in fat


def test_pallas_vmem_budget_is_configurable(tmp_path):
    root = _tree(tmp_path, {
        "repro/kernels/fat.py": _kernel_fixture("""
            def fat_count(q):
                return pl.pallas_call(
                    _kernel,
                    grid=(1,),
                    in_specs=[pl.BlockSpec((2048, 2048), lambda i: (0, 0))],
                    out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
                    out_shape=jax.ShapeDtypeStruct((8, 8), jnp.int32),
                )(q.astype(jnp.float32))
        """),
    })
    assert _findings(root, "pallas-kernel-contract")
    assert _findings(root, "pallas-kernel-contract",
                     vmem_budget_bytes=32 * 1024 * 1024) == []


# ---------------------------------------------------------------------------
# retrace-hygiene
# ---------------------------------------------------------------------------

def test_retrace_hygiene_fixture(tmp_path):
    root = _tree(tmp_path, {
        # violations: coercion of a traced value; branch on a traced param
        "repro/kernels/traced.py": """
            import jax

            @jax.jit
            def step(counts, k):
                if k > 0:
                    counts = counts + 1
                return float(counts)

            def host_side(x):
                return float(x)   # not traced: legal
        """,
        # clean twin: shape math coercions and is-None branches are static
        "repro/kernels/clean.py": """
            import jax

            @jax.jit
            def step(counts, mask=None):
                n = int(counts.shape[0])
                if mask is not None:
                    counts = counts * mask
                return counts
        """,
    })
    got = _findings(root, "retrace-hygiene")
    assert [(f.path, f.line) for f in got] == [
        ("repro/kernels/traced.py", 5), ("repro/kernels/traced.py", 7)]
    assert "branch on traced parameter" in got[0].message
    assert "float() coercion" in got[1].message


def test_queryplan_cache_key_fixture(tmp_path):
    root = _tree(tmp_path, {
        # violations: field hidden from describe(); field opted out of the key
        "repro/core/plan.py": """
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class QueryPlan:
                engine: str
                k: int
                secret: int
                debug: str = dataclasses.field(default="", compare=False)

                def describe(self):
                    return dict(engine=self.engine, k=self.k, debug=self.debug)
        """,
    })
    got = _findings(root, "retrace-hygiene")
    msgs = "\n".join(f.message for f in got)
    assert "'secret' missing from describe()" in msgs
    assert "'debug' opts out of the cache key" in msgs

    clean = _tree(tmp_path / "clean", {
        "repro/core/plan.py": """
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class QueryPlan:
                engine: str
                k: int
                params: tuple    # allowlisted derived key

                def describe(self):
                    return dict(engine=self.engine, k=self.k)
        """,
    })
    assert _findings(clean, "retrace-hygiene") == []

    thawed = _tree(tmp_path / "thawed", {
        "repro/core/plan.py": """
            import dataclasses

            @dataclasses.dataclass
            class QueryPlan:
                k: int

                def describe(self):
                    return dict(k=self.k)
        """,
    })
    got = _findings(thawed, "retrace-hygiene")
    assert len(got) == 1 and "frozen=True" in got[0].message


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------

def test_lock_discipline_fixture(tmp_path):
    root = _tree(tmp_path, {
        # violation: _q written under the lock, read without it
        "repro/serve/scheduler.py": """
            import threading

            class Queue:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._q = []

                def offer(self, x):
                    with self._lock:
                        self._q.append(x)

                def depth(self):
                    return len(self._q)   # line 13: unlocked read
        """,
        # clean twin: every access locked, incl. the lock-private helper
        # pattern (helper writes in its own body, called only under lock)
        "repro/serve/metrics.py": """
            import threading

            class Metrics:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._tenants = {}
                    self._hb = object()

                def _tenant(self, name):
                    t = self._tenants.get(name)
                    if t is None:
                        t = self._tenants[name] = []
                    return t

                def record(self, name, v):
                    with self._lock:
                        self._tenant(name).append(v)
                    self._hb.beat(name)   # plain method call: not a write

                def snapshot(self):
                    with self._lock:
                        return dict(self._tenants)
        """,
    })
    got = _findings(root, "lock-discipline")
    assert [(f.path, f.line) for f in got] == [("repro/serve/scheduler.py", 13)]
    assert "without holding self._lock" in got[0].message


def test_lock_discipline_flags_unlocked_write(tmp_path):
    root = _tree(tmp_path, {
        "repro/serve/frontend.py": """
            import threading

            class Registry:
                def __init__(self):
                    self._reg = threading.Condition()
                    self._tenants = {}

                def register(self, name, svc):
                    with self._reg:
                        self._tenants[name] = svc

                def evict(self, name):
                    self._tenants.pop(name, None)   # line 13: unlocked write
        """,
    })
    got = _findings(root, "lock-discipline")
    assert len(got) == 1
    assert got[0].line == 13 and "written" in got[0].message


# ---------------------------------------------------------------------------
# wall-clock / broad-except
# ---------------------------------------------------------------------------

def test_wall_clock_fixture(tmp_path):
    root = _tree(tmp_path, {
        "repro/serve/timing.py": """
            import time
            from time import time as now

            def bench(fn):
                t0 = time.time()
                fn()
                return time.time() - t0

            def bench2(fn):
                t0 = now()          # aliased import still wall-clock... but
                t1 = time.perf_counter()   # perf_counter is the fix
                return t1 - t0
        """,
        # the by-design carve-out: cross-process heartbeat deadlines
        "repro/runtime/fault_tolerance.py": """
            import time

            def beat():
                return time.time()
        """,
    })
    got = _findings(root, "wall-clock")
    assert [f.line for f in got] == [5, 7]
    assert all(f.path == "repro/serve/timing.py" for f in got)
    assert "perf_counter" in got[0].message


def test_wall_clock_bare_import(tmp_path):
    root = _tree(tmp_path, {
        "repro/launch/t.py": """
            from time import time

            def bench():
                return time()
        """,
    })
    assert [f.line for f in _findings(root, "wall-clock")] == [4]


def test_broad_except_fixture(tmp_path):
    root = _tree(tmp_path, {
        "repro/launch/h.py": """
            def risky():
                try:
                    work()
                except Exception:      # line 4
                    pass
                try:
                    work()
                except (ValueError, BaseException):   # line 8
                    pass
                try:
                    work()
                except:                # line 12: bare
                    pass
                try:
                    work()
                except (KeyError, OSError):   # clean: named failures
                    raise
        """,
    })
    got = _findings(root, "broad-except")
    assert [f.line for f in got] == [4, 8, 12]
    assert "bare except" in got[2].message


# ---------------------------------------------------------------------------
# Suppression syntax
# ---------------------------------------------------------------------------

def test_suppression_round_trip(tmp_path):
    root = _tree(tmp_path, {
        "repro/launch/s.py": """
            def boundary():
                try:
                    work()
                except Exception:  # genielint: ignore[broad-except]
                    record()
                try:
                    work()
                # genielint: ignore[broad-except]
                except Exception:
                    record()
                try:
                    work()
                # genielint: ignore[wall-clock]
                except Exception:      # wrong rule named: NOT suppressed
                    record()
        """,
    })
    all_findings = run_lint(root, rules=["broad-except"])
    assert [(f.line, f.suppressed) for f in all_findings] == [
        (4, True), (9, True), (14, False)]
    # suppressed findings are still reported (for the JSON trail) but do
    # not count against the gate
    assert len(_findings(root, "broad-except")) == 1


def test_suppression_requires_comment_only_line(tmp_path):
    """A directive buried in trailing code two lines up must not leak onto
    the next statement -- only the finding's own line or an immediately
    preceding comment-only line suppresses."""
    root = _tree(tmp_path, {
        "repro/launch/s.py": """
            def boundary():
                x = 1  # genielint: ignore[broad-except]
                y = 2
                try:
                    work()
                except Exception:
                    record()
        """,
    })
    got = run_lint(root, rules=["broad-except"])
    assert [(f.line, f.suppressed) for f in got] == [(6, False)]


# ---------------------------------------------------------------------------
# The HEAD gate + CLI
# ---------------------------------------------------------------------------

def test_repo_is_clean_at_head():
    """The invariant the CI lane enforces: zero unsuppressed findings over
    src/ with every rule enabled.  If this fails, fix the violation (or,
    when the catch-all/wall-clock IS the design, justify it at the site
    with an inline ignore) -- do not widen the config allowlists."""
    findings = [f for f in run_lint(_SRC) if not f.suppressed]
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_exit_codes(tmp_path):
    env = dict(os.environ, PYTHONPATH=_REPO)
    report = tmp_path / "lint.json"
    out = subprocess.run(
        [sys.executable, "-m", "tools.genielint", "--json", str(report)],
        cwd=_REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "genielint: clean" in out.stdout
    rep = json.loads(report.read_text())
    assert rep["ok"] is True and rep["tool"] == "genielint"
    assert rep["n_unsuppressed"] == 0

    bad_root = _tree(tmp_path, {
        "repro/launch/bad.py": """
            import time

            def bench():
                return time.time()
        """,
    })
    out = subprocess.run(
        [sys.executable, "-m", "tools.genielint", "--root", bad_root,
         "--json", str(report)],
        cwd=_REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 1
    assert "wall-clock" in out.stdout
    assert json.loads(report.read_text())["ok"] is False


def test_cli_rejects_unknown_rule():
    env = dict(os.environ, PYTHONPATH=_REPO)
    out = subprocess.run(
        [sys.executable, "-m", "tools.genielint", "--rules", "no-such-rule"],
        cwd=_REPO, env=env, capture_output=True, text=True)
    assert out.returncode == 2
    assert "unknown rule" in out.stderr


# ---------------------------------------------------------------------------
# Config cross-checks against the live code
# ---------------------------------------------------------------------------

def test_kernel_dtype_policy_matches_registry():
    """config.kernel_out_dtypes must equal the registry's widest count
    dtype: kernels emit exact int32 and as_count_dtype only ever narrows,
    so a drift in either direction (a kernel emitting float, or the
    registry widening past int32) breaks the contract."""
    import jax.numpy as jnp

    from repro.core.match import as_count_dtype

    widest = as_count_dtype(jnp.zeros((), jnp.int32), 1 << 30).dtype.name
    assert set(DEFAULT.kernel_out_dtypes) == {widest}
    for mc in (1, 127, 128, 32767, 32768, 1 << 24):
        narrowed = as_count_dtype(jnp.zeros((), jnp.int32), mc).dtype
        assert narrowed.itemsize <= jnp.dtype(widest).itemsize


@pytest.mark.parametrize("paths", [
    DEFAULT.executor_modules, DEFAULT.lock_modules,
    DEFAULT.wall_clock_allow, DEFAULT.traced_modules,
])
def test_config_scopes_point_at_real_files(paths):
    """A rename must not silently de-scope a rule: every path named in the
    config exists under src/."""
    for rel in paths:
        assert os.path.exists(os.path.join(_SRC, rel)), rel


def test_all_rules_registered():
    from tools.genielint.core import ALL_RULES, _load_rules
    _load_rules()
    assert set(ALL_RULES) == {
        "executor-sovereignty", "pallas-kernel-contract", "retrace-hygiene",
        "lock-discipline", "wall-clock", "broad-except"}
