"""LSH schemes: Eqn-1 collision probabilities, tau-ANN bounds (section IV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lsh import e2lsh, minhash, rbh, rehash, simhash, tau_ann


def test_e2lsh_collision_matches_psi(rng):
    """Empirical collision rate of h(p)=floor((a.p+b)/w) ~= psi_2(dist)."""
    d, m, w = 8, 4000, 4.0
    params = e2lsh.make(jax.random.PRNGKey(0), d=d, m=m, w=w)
    x = jnp.zeros((d,))
    for dist in (0.5, 1.0, 2.0, 4.0):
        y = x.at[0].add(dist)
        hx, hy = e2lsh.raw_hash(params, x), e2lsh.raw_hash(params, y)
        emp = float(jnp.mean((hx == hy).astype(jnp.float32)))
        theory = float(e2lsh.collision_prob(dist, w, 2))
        assert abs(emp - theory) < 0.03, (dist, emp, theory)


def test_e2lsh_similarity_monotone():
    dists = jnp.array([0.1, 0.5, 1.0, 2.0, 5.0, 10.0])
    probs = e2lsh.collision_prob(dists, 4.0, 2)
    assert bool(jnp.all(jnp.diff(probs) < 0))
    probs1 = e2lsh.collision_prob(dists, 4.0, 1)
    assert bool(jnp.all(jnp.diff(probs1) < 0))


def test_rbh_collision_matches_laplacian_kernel(rng):
    """Pr[h(p)=h(q)] == exp(-||p-q||_1 / sigma)  (Rahimi-Recht / paper IV-A3)."""
    d, m, sigma = 4, 4000, 2.0
    params = rbh.make(jax.random.PRNGKey(1), d=d, m=m, sigma=sigma, n_buckets=1 << 20)
    x = jnp.zeros((d,))
    for l1 in (0.2, 1.0, 3.0):
        y = x + l1 / d
        hx = rbh.raw_hash(params, x)
        hy = rbh.raw_hash(params, y)
        emp = float(jnp.mean(jnp.all(hx == hy, axis=-1).astype(jnp.float32)))
        theory = float(np.exp(-l1 / sigma))
        assert abs(emp - theory) < 0.035, (l1, emp, theory)


def test_minhash_collision_matches_jaccard(rng):
    m = 3000
    params = minhash.make(jax.random.PRNGKey(2), m=m, n_buckets=1 << 20)
    a = np.arange(0, 30)
    b = np.arange(15, 45)   # |inter|=15, |union|=45 -> J = 1/3
    L = 64
    ae = np.full(L, -1); ae[:30] = a
    be = np.full(L, -1); be[:30] = b
    av = ae >= 0; bv = be >= 0
    ha = minhash.hash_sets(params, jnp.asarray(ae)[None], jnp.asarray(av)[None])
    hb = minhash.hash_sets(params, jnp.asarray(be)[None], jnp.asarray(bv)[None])
    emp = float(jnp.mean((ha == hb).astype(jnp.float32)))
    assert abs(emp - 1 / 3) < 0.04, emp


def test_simhash_collision_matches_angular(rng):
    d, m = 16, 5000
    params = simhash.make(jax.random.PRNGKey(3), d=d, m=m)
    x = jnp.asarray(rng.standard_normal(d), dtype=jnp.float32)
    y = x + 0.7 * jnp.asarray(rng.standard_normal(d), dtype=jnp.float32)
    emp = float(jnp.mean((simhash.hash_points(params, x) == simhash.hash_points(params, y)).astype(jnp.float32)))
    theory = float(simhash.similarity(x, y))
    assert abs(emp - theory) < 0.03


def test_rehash_deterministic_and_bounded(rng):
    sig = jnp.asarray(rng.integers(-(2**20), 2**20, size=(50, 8)), dtype=jnp.int32)
    seeds = rehash.make_seeds(jax.random.PRNGKey(4), 8)
    out1 = rehash.rehash(sig, seeds, 67)
    out2 = rehash.rehash(sig, seeds, 67)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert int(jnp.min(out1)) >= 0 and int(jnp.max(out1)) < 67


# ---------------------------------------------------------------------------
# tau-ANN theory (section IV-B)
# ---------------------------------------------------------------------------

def test_m_theorem41():
    assert tau_ann.m_theorem41(0.06, 0.06) == 2174  # paper: m = 2 ln(3/d)/e^2


def test_required_m_reproduces_fig8():
    """Paper Fig 8: max_s min-m == 237 at eps=delta=0.06.  Our exact binomial
    window gives 238 (the +-1 is the paper's floor/ceil convention; see
    tau_ann.prob_within docstring)."""
    m = tau_ann.required_m(0.06, 0.06, s_grid=101)
    assert 232 <= m <= 242
    # worst case sits near s=0.5 as in the paper
    assert tau_ann.min_m_for_similarity(0.5, 0.06, 0.06) in range(228, 242)
    # and is far below the Theorem 4.1 bound
    assert m < tau_ann.m_theorem41(0.06, 0.06) / 5


def test_match_count_estimates_similarity(rng):
    """Theorem 4.1 empirically: |MC/m - sim| <= eps + 1/D w.p. >= 1 - delta."""
    eps = delta = 0.1
    m = tau_ann.required_m(eps, delta)
    d = 8
    params = e2lsh.make(jax.random.PRNGKey(5), d=d, m=m, w=4.0, n_buckets=8192)
    pts = jnp.asarray(rng.standard_normal((200, d)), dtype=jnp.float32)
    q = pts[0] + 0.3
    sig_p = e2lsh.hash_points(params, pts)
    sig_q = e2lsh.hash_points(params, q)
    mc = jnp.sum((sig_p == sig_q[None, :]).astype(jnp.int32), axis=-1)
    sims = e2lsh.similarity(params, pts, q)
    err = np.abs(np.asarray(mc) / m - np.asarray(sims))
    frac_ok = float(np.mean(err <= eps + 1 / 8192 + 0.02))
    assert frac_ok >= 1 - 2 * delta, frac_ok
