"""Shotgun-and-Assembly search (paper section V): n-grams, verification,
documents, relational.

Formerly hypothesis property tests; rewritten as seeded-random parametrized
cases so the tier-1 suite runs on environments without hypothesis.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GenieIndex, match
from repro.core.sa import document, ngram, relational, verify


def _rand_seq(draw, max_size=24) -> str:
    return "".join(draw.choice(list("abcd"), size=int(draw.integers(0, max_size + 1))))


@pytest.mark.parametrize("case", range(40))
def test_minsum_count_vectors_equal_exact_mc_when_no_collisions(case):
    """Lemma 5.1 via count vectors: with a large bucket space (no collisions
    among these tiny alphabets), MINSUM == exact ordered-n-gram match count."""
    draw = np.random.default_rng(6000 + case)
    s, q = _rand_seq(draw), _rand_seq(draw)
    n, v = 3, 1 << 16
    cs = ngram.count_vector(s, n, v)
    cq = ngram.count_vector(q, n, v)
    got = int(np.minimum(cs, cq).sum())
    assert got == ngram.exact_match_count(s, q, n)


@pytest.mark.parametrize("case", range(40))
def test_bucketised_mc_upper_bounds_exact(case):
    """min(a1+a2, b1+b2) >= min(a1,b1)+min(a2,b2): bucket collisions can only
    OVER-count, so the Theorem 5.1 filter never loses a true candidate."""
    draw = np.random.default_rng(7000 + case)
    s, q = _rand_seq(draw), _rand_seq(draw)
    v = int(draw.integers(4, 65))
    n = 3
    cs = ngram.count_vector(s, n, v)
    cq = ngram.count_vector(q, n, v)
    assert int(np.minimum(cs, cq).sum()) >= ngram.exact_match_count(s, q, n)


@pytest.mark.parametrize("case", range(30))
def test_count_filter_bound_theorem51(case):
    draw = np.random.default_rng(8000 + case)
    s, q = _rand_seq(draw), _rand_seq(draw)
    """Theorem 5.1: MC >= max(|Q|,|S|) - n + 1 - ed*n."""
    n = 2
    if len(s) < n or len(q) < n:
        return
    import numpy as _np

    def ed(a, b):
        la, lb = len(a), len(b)
        dmat = _np.zeros((lb + 1, la + 1), dtype=int)
        dmat[0, :] = _np.arange(la + 1)
        dmat[:, 0] = _np.arange(lb + 1)
        for j in range(1, lb + 1):
            for i in range(1, la + 1):
                dmat[j, i] = min(dmat[j - 1, i - 1] + (a[i - 1] != b[j - 1]),
                                 dmat[j, i - 1] + 1, dmat[j - 1, i] + 1)
        return dmat[lb, la]

    mc = ngram.exact_match_count(s, q, n)
    bound = ngram.count_filter_bound(len(q), len(s), ed(s, q), n)
    assert mc >= bound


@pytest.mark.parametrize("case", range(30))
def test_edit_distance_property(case):
    draw = np.random.default_rng(9000 + case)
    la, lb = int(draw.integers(0, 15)), int(draw.integers(0, 15))
    rng = np.random.default_rng(int(draw.integers(0, 10**6)))
    a = rng.integers(0, 4, la)
    b = rng.integers(0, 4, lb)
    L = 16
    ap = np.full(L, -1, np.int32); ap[:la] = a
    bp = np.full(L, -2, np.int32); bp[:lb] = b
    got = int(verify.edit_distance(jnp.asarray(ap), jnp.int32(la), jnp.asarray(bp), jnp.int32(lb)))
    # reference
    d = np.zeros((lb + 1, la + 1), dtype=int)
    d[0, :] = np.arange(la + 1); d[:, 0] = np.arange(lb + 1)
    for j in range(1, lb + 1):
        for i in range(1, la + 1):
            d[j, i] = min(d[j - 1, i - 1] + (a[i - 1] != b[j - 1]), d[j, i - 1] + 1, d[j - 1, i] + 1)
    assert got == d[lb, la]


def test_sequence_search_end_to_end(rng):
    """Mutated query finds its source sequence; certificate checks out."""
    from repro.data.pipeline import mutate_sequence, synthetic_sequences

    seqs = synthetic_sequences(300, length=40, seed=1)
    n, v, K = 3, 4096, 32
    idx = GenieIndex.build_minsum(ngram.count_vectors(seqs, n, v), max_count=127)
    target = 17
    qstr = mutate_sequence(seqs[target], 0.2, seed=2)
    qv = ngram.count_vector(qstr, n, v)[None]
    res = idx.search(qv, k=K)
    cand_ids = np.asarray(res.ids[0])
    assert target in cand_ids[:K]
    # verification: edit distance picks the target as top-1
    enc, lens = ngram.encode_sequences([seqs[i] if i >= 0 else "" for i in cand_ids], 48)
    qenc, qlen = ngram.encode_sequences([qstr], 48)
    out = verify.verify_topk(
        jnp.asarray(qenc[0]), jnp.int32(qlen[0]), jnp.asarray(enc), jnp.asarray(lens),
        jnp.asarray(np.asarray(res.counts[0])), k=1, n=n,
    )
    best = int(np.asarray(out["order"])[0])
    assert int(cand_ids[best]) == target


def test_document_search_inner_product(rng):
    docs = ["the cat sat on the mat", "dogs chase cats", "jax on tpu pods",
            "inverted index similarity search", "cat and dog and bird"]
    v = 2048
    idx = GenieIndex.build_ip(document.binary_vectors(docs, v), max_count=64)
    q = document.binary_vectors(["cat dog"], v)
    res = idx.search(q, k=2)
    counts = np.asarray(res.counts[0])
    # oracle overlaps
    want = sorted((document.exact_overlap("cat dog", d) for d in docs), reverse=True)[:2]
    assert list(counts) == want


def test_relational_range_search(rng):
    vals = rng.standard_normal((400, 6))
    disc = relational.fit_discretizer(vals, n_bins=1024)
    dv = disc.transform(vals)
    idx = GenieIndex.build_relational(dv)
    lo, hi = relational.point_range_queries(dv[:3], radius=50)
    res = idx.search((lo, hi), k=1)
    # the tuple itself always matches all its own attributes
    assert np.all(np.asarray(res.counts)[:, 0] == 6)
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(3))
    # oracle agreement
    want = relational.exact_range_count(dv, lo, hi)
    got = np.asarray(match.match_range(jnp.asarray(dv), jnp.asarray(lo), jnp.asarray(hi)))
    assert np.array_equal(got, want)
