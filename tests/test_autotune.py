"""Autotuner contract suite: tuned plans are a pure perf knob.

Three properties pin the autotuner (core/autotune.py) to safety:

  * Parity -- a plan carrying adversarial-but-valid tile_overrides returns
    bit-identical ids/counts to the default plan, for every engine x
    signature layout x selection method.  Tile sizes change the grid, never
    the math (Theorem 3.1 count-bound semantics are tile-agnostic).
  * Fallback -- a missing/corrupt/foreign-machine cache silently keeps the
    defaults: autotuning is an accelerator, never a correctness dependency.
  * Keying -- tile_overrides are part of the QueryPlan hash (distinct
    executables) and surface in describe() (genielint retrace-hygiene).
"""
import json
import os

import numpy as np
import pytest

from repro.core import GenieIndex, SegmentedIndex, autotune, cpq, engines
from repro.core import plan as plan_lib
from repro.core.types import Engine, SearchParams, SignatureLayout, TopKMethod

ALL_ENGINES = sorted(engines.available(), key=lambda e: e.value)
PACKED_ENGINES = [e for e in ALL_ENGINES if engines.get(e).supports_packed]
ALL_METHODS = [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT]

# adversarial-but-valid: every knob at its alignment floor forces the
# largest possible grid (most steps, most edge tiles) the kernels support
FLOOR_TILES = {"tile_q": 8, "tile_n": 128, "tile_v": 128, "tile_m": 128}
# and oversized knobs clamp down to one big step via pick_tile
HUGE_TILES = {"tile_q": 4096, "tile_n": 65536, "tile_v": 8192, "tile_m": 8192}


def _case(engine: Engine, n=101, q=4, seed=0):
    model = engines.get(engine)
    raw, queries, mc = model.example(np.random.default_rng(seed), n, q)
    data = model.prepare_data(raw)
    return model, raw, data, queries, model.resolve_max_count(data, mc)


def _assert_same(got, want, label=""):
    assert np.array_equal(np.asarray(got.ids), np.asarray(want.ids)), label
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts)), label


# ---------------------------------------------------------------------------
# Parity: adversarial tiles, engine x layout x method
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("method", ALL_METHODS)
@pytest.mark.parametrize("tiles", [FLOOR_TILES, HUGE_TILES],
                         ids=["floor", "huge"])
def test_tiled_plan_parity_wide(engine, method, tiles):
    """Kernel plans with floor/huge tile overrides reproduce the sort-select
    oracle bit-for-bit on the WIDE layout."""
    k = 9
    model, raw, data, queries, mc = _case(engine)
    q_wide = model.prepare_queries(queries)
    oracle = cpq.sort_select(model.reference(data, q_wide),
                             SearchParams(k=k, max_count=mc))
    plan = plan_lib.plan_search(model, k, mc, part_rows=(data.shape[0],),
                                method=method, use_kernel=True,
                                tile_overrides=tiles)
    assert dict(plan.tile_overrides)  # engine-relevant knobs survived
    got = plan_lib.execute(plan, data, q_wide)
    _assert_same(got, oracle, f"{engine.value} {method.value} {tiles}")


@pytest.mark.parametrize("engine", PACKED_ENGINES)
@pytest.mark.parametrize("method", ALL_METHODS)
def test_tiled_plan_parity_packed(engine, method):
    """PACKED plans (fused kernel path included) are tile-agnostic too."""
    k = 7
    model, raw, data, queries, mc = _case(engine, n=130)
    packed = model.pack_data(data)
    q_packed = model.prepare_queries_for(queries, SignatureLayout.PACKED)
    oracle = cpq.sort_select(model.reference(data, model.prepare_queries(queries)),
                             SearchParams(k=k, max_count=mc))
    default = plan_lib.plan_search(model, k, mc, part_rows=(data.shape[0],),
                                   method=method, use_kernel=True,
                                   signature_layout="packed")
    tiled = plan_lib.plan_search(model, k, mc, part_rows=(data.shape[0],),
                                 method=method, use_kernel=True,
                                 signature_layout="packed",
                                 tile_overrides=FLOOR_TILES)
    _assert_same(plan_lib.execute(default, packed, q_packed), oracle,
                 f"{engine.value} {method.value} packed default")
    _assert_same(plan_lib.execute(tiled, packed, q_packed), oracle,
                 f"{engine.value} {method.value} packed tiled")


def test_segmented_tiles_and_layout_switch_parity():
    """Tile overrides ride the host part loop, and a tuned layout switch
    (SEGMENTED -> MULTILOAD host) returns identical results."""
    model, raw, data, queries, mc = _case(Engine.EQ, n=150)
    seg = SegmentedIndex(engine=Engine.EQ, max_count=mc, use_kernel=True)
    for a, b in ((0, 40), (40, 41), (41, 150)):
        seg.add(raw[a:b])
    base = seg.search(queries, k=5)
    _assert_same(seg.search(queries, k=5, tile_overrides={"tile_n": 128}),
                 base, "segmented tiled")

    cache = autotune.AutotuneCache()
    cache.put(autotune.TunedEntry(
        engine="eq", signature_layout="wide",
        n_bucket=autotune.shape_bucket(seg.n_objects),
        w_bucket=autotune.shape_bucket(raw.shape[1]),
        tile_overrides=(("tile_n", 128),), layout="multiload_host",
        speedup=1.3))
    _assert_same(seg.search(queries, k=5, autotune=cache), base,
                 "tuned layout switch")


def test_genie_index_autotune_parity():
    """GenieIndex.search(autotune=cache) applies the cached tiles and still
    matches the untuned search exactly."""
    model, raw, data, queries, mc = _case(Engine.COSINE, n=140)
    idx = GenieIndex.build(Engine.COSINE, raw, max_count=mc, use_kernel=True)
    base = idx.search(queries, k=6)
    cache = autotune.AutotuneCache()
    cache.put(autotune.TunedEntry(
        engine="cosine", signature_layout="wide",
        n_bucket=autotune.shape_bucket(idx.stats.n_objects),
        w_bucket=autotune.shape_bucket(data.shape[1]),
        tile_overrides=(("tile_n", 128), ("tile_q", 8)), speedup=1.2))
    _assert_same(idx.search(queries, k=6, autotune=cache), base)


# ---------------------------------------------------------------------------
# Plan cache keying + describe()
# ---------------------------------------------------------------------------

def test_tile_overrides_key_the_plan_cache():
    """Plans differing only in tile_overrides are distinct cache keys with
    distinct executables -- and equal overrides (any spelling) are one key."""
    mk = lambda tiles: plan_lib.plan_search(
        Engine.EQ, 5, 16, part_rows=(64,), use_kernel=True,
        tile_overrides=tiles)
    a, b = mk(None), mk({"tile_n": 256})
    assert a != b and hash(a) != hash(b)
    c = mk([("tile_n", 256)])                 # pair-list spelling, same knobs
    assert b == c and hash(b) == hash(c)
    assert b.describe()["tile_overrides"] == {"tile_n": 256}

    plan_lib.clear_plan_cache()
    model, raw, data, queries, mc = _case(Engine.EQ, n=64)
    q_wide = model.prepare_queries(queries)
    p1 = plan_lib.plan_search(model, 5, mc, part_rows=(64,), use_kernel=True)
    p2 = plan_lib.plan_search(model, 5, mc, part_rows=(64,), use_kernel=True,
                              tile_overrides={"tile_n": 256})
    _assert_same(plan_lib.execute(p2, data, q_wide),
                 plan_lib.execute(p1, data, q_wide))
    assert plan_lib.trace_count(p1) == 1
    assert plan_lib.trace_count(p2) == 1      # separate executable, traced once


# ---------------------------------------------------------------------------
# Validation: pick_tile + plan_search rejections
# ---------------------------------------------------------------------------

def test_pick_tile_validates_inputs():
    from repro.kernels.common import pick_tile

    assert pick_tile(100, 256, 8) in range(8, 105)
    with pytest.raises(ValueError, match="tile_n"):
        pick_tile(100, 256, 0, knob="tile_n")
    with pytest.raises(ValueError, match="tile_q"):
        pick_tile(100, 4, 8, knob="tile_q")   # preferred below align


def test_plan_search_rejects_bad_tiles():
    with pytest.raises(ValueError, match="unknown tile knob"):
        plan_lib.plan_search(Engine.EQ, 3, 16, tile_overrides={"tile_x": 8})
    with pytest.raises(ValueError, match="alignment floor"):
        plan_lib.plan_search(Engine.EQ, 3, 16, tile_overrides={"tile_n": 64})
    with pytest.raises(ValueError, match="use_kernel=False"):
        plan_lib.plan_search(Engine.EQ, 3, 16, use_kernel=False,
                             tile_overrides={"tile_n": 128})
    with pytest.raises(ValueError, match="raw match"):
        plan_lib.plan_search(lambda d, q: None, 3, 16,
                             tile_overrides={"tile_n": 128})
    with pytest.raises(ValueError, match="duplicate"):
        engines.canonical_tile_overrides([("tile_n", 128), ("tile_n", 256)])


# ---------------------------------------------------------------------------
# Cache: round-trip, fingerprint gate, corrupt-file fallback, consult
# ---------------------------------------------------------------------------

def _entry(**kw):
    base = dict(engine="eq", signature_layout="wide", n_bucket=128,
                w_bucket=64, tile_overrides=(("tile_n", 512),), speedup=1.4)
    base.update(kw)
    return autotune.TunedEntry(**base)


def test_cache_roundtrip_and_fingerprint_gate(tmp_path):
    path = tmp_path / "autotune.json"
    cache = autotune.AutotuneCache(path)
    cache.put(_entry())
    cache.save()

    reloaded = autotune.AutotuneCache(path)
    assert reloaded.entries == cache.entries
    hit = reloaded.lookup("eq", "wide", n=100, width=60)  # buckets to 128|64
    assert hit == _entry()
    assert reloaded.lookup("eq", "wide", n=100) == _entry()  # width-agnostic
    assert reloaded.lookup("eq", "wide", n=5000) is None     # other bucket
    assert reloaded.lookup("eq", "wide", n=None) is None

    foreign = autotune.AutotuneCache(path)
    foreign.fingerprint = {"platform": "not-this-machine"}
    assert foreign.lookup("eq", "wide", n=100, width=60) is None


def test_corrupt_cache_degrades_to_defaults(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text("{not json")
    cache = autotune.AutotuneCache(path)
    assert cache.entries == {}
    path.write_text(json.dumps({"version": 99, "fingerprint": {},
                                "entries": {"x": {}}}))
    assert autotune.AutotuneCache(path).entries == {}  # version gate


def test_consult_resolves_specs(tmp_path, monkeypatch):
    assert autotune.consult(None, engine="eq", signature_layout="wide",
                            n=100) is None
    assert autotune.consult(False, engine="eq", signature_layout="wide",
                            n=100) is None
    path = tmp_path / "c.json"
    cache = autotune.AutotuneCache(path)
    cache.put(_entry())
    cache.save()
    autotune.clear_resolved_caches()
    got = autotune.consult(str(path), engine="eq", signature_layout="wide",
                           n=100, width=60)
    assert got == _entry()
    # spec=True routes through GENIE_AUTOTUNE_CACHE
    monkeypatch.setenv("GENIE_AUTOTUNE_CACHE", str(path))
    autotune.clear_resolved_caches()
    assert autotune.consult(True, engine="eq", signature_layout="wide",
                            n=100, width=60) == _entry()
    autotune.clear_resolved_caches()


def test_plan_search_applies_cache_and_explicit_args_win():
    cache = autotune.AutotuneCache()
    cache.put(_entry(tile_overrides=(("tile_n", 512),), candidate_cap=32))
    tuned = plan_lib.plan_search(Engine.EQ, 3, 16, part_rows=(100,),
                                 use_kernel=True, autotune=cache,
                                 tune_width=60)
    assert dict(tuned.tile_overrides) == {"tile_n": 512}
    assert tuned.params.candidate_cap == 32
    explicit = plan_lib.plan_search(Engine.EQ, 3, 16, part_rows=(100,),
                                    use_kernel=True, autotune=cache,
                                    tune_width=60, candidate_cap=48,
                                    tile_overrides={"tile_n": 256})
    assert dict(explicit.tile_overrides) == {"tile_n": 256}
    assert explicit.params.candidate_cap == 48
    # kernel-path knobs never leak onto the XLA path
    xla = plan_lib.plan_search(Engine.EQ, 3, 16, part_rows=(100,),
                               use_kernel=False, autotune=cache,
                               tune_width=60)
    assert xla.tile_overrides == ()
    assert xla.params.candidate_cap == 32


# ---------------------------------------------------------------------------
# tune() end-to-end (tiny budget) + service.tune smoke
# ---------------------------------------------------------------------------

def test_tune_end_to_end_parity_and_cache():
    """A real (tiny-budget) tuning run: the entry lands in the cache, keys
    the shape correctly, and searching through it changes nothing."""
    model, raw, data, queries, mc = _case(Engine.EQ, n=256, q=8)
    cache = autotune.AutotuneCache()
    entry = autotune.tune(model, raw, queries, 5, mc, budget=2, repeats=1,
                          cache=cache, save=False)
    assert entry.key() in cache.entries
    assert entry.n_bucket == autotune.shape_bucket(256)
    assert entry.speedup >= 1.0          # tuned never records a regression

    idx = GenieIndex.build(Engine.EQ, raw, max_count=mc, use_kernel=True)
    _assert_same(idx.search(queries, k=5, autotune=cache),
                 idx.search(queries, k=5))


def test_tune_prepared_requires_max_count():
    model, raw, data, queries, mc = _case(Engine.EQ, n=64)
    with pytest.raises(ValueError, match="max_count"):
        autotune.tune(model, data, model.prepare_queries(queries), 3,
                      None, prepared=True)


def test_service_tune_smoke():
    """RetrievalService.tune wires the serving corpus into the tuner and
    installs the winning cache; results stay bit-identical."""
    from repro.serve.retrieval import RetrievalService

    rng = np.random.default_rng(11)
    pts = rng.standard_normal((150, 16)).astype(np.float32)
    q = pts[40:45] + 0.01
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=32)
    svc.add(list(range(150)), embeddings=pts)
    base, _ = svc.search(None, k=4, embeddings=q)
    entry = svc.tune(None, k=4, embeddings=q, budget=2, repeats=1,
                     save=False)
    assert isinstance(entry, autotune.TunedEntry)
    assert svc.autotune is not None
    tuned, _ = svc.search(None, k=4, embeddings=q)
    _assert_same(tuned, base)
