"""Multiple loading (paper section III-D) and merge invariants.

Formerly hypothesis property tests; rewritten as seeded-random parametrized
cases so the tier-1 suite runs on environments without hypothesis.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GenieIndex, cpq, match, merge, multiload
from repro.core.types import SearchParams


@pytest.mark.parametrize("case", range(10))
def test_multiload_scan_equals_full_search(case):
    draw = np.random.default_rng(5000 + case)
    n = int(draw.integers(20, 201))
    parts = int(draw.integers(1, 7))
    k = int(draw.integers(1, 9))
    sigs = draw.integers(0, 8, (n, 12)).astype(np.int32)
    qs = draw.integers(0, 8, (3, 12)).astype(np.int32)
    idx = GenieIndex.build_lsh(sigs, use_kernel=False)
    full = idx.search(qs, k=k)
    part = idx.search_multiload(qs, k=k, n_parts=parts)
    assert np.array_equal(np.asarray(full.counts), np.asarray(part.counts))


def test_multiload_host_loop_matches_scan(rng):
    sigs = rng.integers(0, 8, (120, 12)).astype(np.int32)
    qs = rng.integers(0, 8, (4, 12)).astype(np.int32)
    params = SearchParams(k=5, max_count=12)
    parts = [sigs[i * 40 : (i + 1) * 40] for i in range(3)]
    host = multiload.multiload_search_host(parts, jnp.asarray(qs), params, match.match_eq)
    idx = GenieIndex.build_lsh(sigs, use_kernel=False)
    full = idx.search(qs, k=5)
    assert np.array_equal(np.asarray(host.counts), np.asarray(full.counts))
    assert np.array_equal(np.asarray(host.ids), np.asarray(full.ids))


def test_merge_with_unequal_part_k(rng):
    """Merging buffers whose per-part k exceeds the global k still works."""
    ids = rng.integers(0, 1000, (3, 2, 9)).astype(np.int32)
    counts = np.sort(rng.integers(0, 50, (3, 2, 9)), axis=-1)[..., ::-1].astype(np.int32)
    res = merge.merge_topk(jnp.asarray(ids), jnp.asarray(counts), k=4)
    flat = counts.transpose(1, 0, 2).reshape(2, -1)
    want = np.sort(flat, axis=-1)[:, ::-1][:, :4]
    assert np.array_equal(np.asarray(res.counts), want)


def test_merge_part_order_invariant(rng):
    """Merge of disjoint partitions is invariant to part order (the property
    the hierarchical multi-pod merge relies on).  NOTE: parts must be
    disjoint -- merge never sums counts across parts (documented contract)."""
    counts = np.sort(rng.integers(0, 30, (4, 2, 6)), axis=-1)[..., ::-1].astype(np.int32)
    ids = np.arange(4 * 2 * 6, dtype=np.int32).reshape(4, 2, 6)  # disjoint ids
    fwd = merge.merge_topk(jnp.asarray(ids), jnp.asarray(counts), k=6)
    perm = [2, 0, 3, 1]
    rev = merge.merge_topk(jnp.asarray(ids[perm]), jnp.asarray(counts[perm]), k=6)
    assert np.array_equal(np.asarray(fwd.counts), np.asarray(rev.counts))
    assert set(map(tuple, np.asarray(fwd.ids))) == set(map(tuple, np.asarray(fwd.ids)))


def test_count_dtype_bounding():
    """The Bitmap-Counter bit-bounding helper (paper section III-C)."""
    c = jnp.arange(10, dtype=jnp.int32)
    assert match.as_count_dtype(c, 100).dtype == jnp.int8
    assert match.as_count_dtype(c, 1000).dtype == jnp.int16
    assert match.as_count_dtype(c, 10**6).dtype == jnp.int32


def test_match_eq_int8_matches_int32(rng):
    """Hillclimb C1: int8 signatures are bit-identical to int32."""
    d8 = rng.integers(0, 67, (200, 24)).astype(np.int8)
    q8 = rng.integers(0, 67, (4, 24)).astype(np.int8)
    got8 = np.asarray(match.match_eq(jnp.asarray(d8), jnp.asarray(q8)))
    got32 = np.asarray(match.match_eq(jnp.asarray(d8.astype(np.int32)),
                                      jnp.asarray(q8.astype(np.int32))))
    assert np.array_equal(got8, got32)
