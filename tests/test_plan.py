"""Planner parity suite: execute(plan) must reproduce the pre-planner results
bit-for-bit for every engine x layout x selection method.

The four legacy entry points (GenieIndex.search, SegmentedIndex.search /
search_multiload, multiload_search_host, distributed.make_*_search_step) are
now thin adapters over core/plan.py; this suite pins the consolidated
executor to the behaviour the four copies had: identical ids, counts, and
thresholds against the sort-select oracle, across

    6 engines x {monolithic, segmented, multiload, distributed} x
    {CPQ, SPQ, SORT}

plus the plan cache contract (same layout shape -> no retrace, counted via
the per-plan trace counter) and the sharded-serving parity leg
(RetrievalService(mesh=...) == single-device service, subprocess with 8
forced CPU devices).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import GenieIndex, SegmentedIndex, cpq, engines
from repro.core import plan as plan_lib
from repro.core.types import Engine, SearchParams, TopKMethod

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

ALL_ENGINES = sorted(engines.available(), key=lambda e: e.value)
ALL_METHODS = [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT]

# uneven on purpose: a 1-row segment, a segment smaller than k, a big one
CUTS = [0, 3, 4, 40, 90, 101]


def _case(engine: Engine, n=101, q=4, seed=0):
    model = engines.get(engine)
    raw, queries, mc = model.example(np.random.default_rng(seed), n, q)
    data = model.prepare_data(raw)
    return model, raw, data, queries, model.resolve_max_count(data, mc)


def _assert_same(got, want, label=""):
    assert np.array_equal(np.asarray(got.ids), np.asarray(want.ids)), label
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts)), label


# ---------------------------------------------------------------------------
# Parity: engine x layout x method (single-process layouts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("method", ALL_METHODS)
def test_planner_layout_parity(engine, method):
    """MONOLITHIC, SEGMENTED, MULTILOAD(scan), and MULTILOAD(host) plans all
    reproduce the sort oracle's ids and counts exactly, and their thresholds
    agree with the k-th count (Theorem 3.1)."""
    k = 9
    model, raw, data, queries, mc = _case(engine)
    oracle = cpq.sort_select(
        model.reference(data, model.prepare_queries(queries)),
        SearchParams(k=k, max_count=mc),
    )

    idx = GenieIndex.build(engine, raw, max_count=mc, use_kernel=False)
    seg = SegmentedIndex(engine=engine, max_count=mc, use_kernel=False)
    for a, b in zip(CUTS, CUTS[1:]):
        seg.add(raw[a:b])

    results = {
        "monolithic": idx.search(queries, k=k, method=method),
        "segmented": seg.search(queries, k=k, method=method),
        "multiload-scan": idx.search_multiload(queries, k=k, n_parts=4,
                                               method=method),
        "multiload-host": seg.search_multiload(queries, k=k, method=method),
    }
    for layout, got in results.items():
        _assert_same(got, oracle, f"{engine.value} {method.value} {layout}")
        if layout == "monolithic" and method == TopKMethod.SPQ:
            continue  # SPQ's bucket threshold is its own (pre-planner) value
        assert np.array_equal(np.asarray(got.threshold),
                              np.asarray(oracle.counts)[:, -1]), \
            f"{engine.value} {method.value} {layout} threshold"


# The old test_planner_is_the_only_selector string-grep lived here; the
# invariant is now enforced repo-wide by genielint's executor-sovereignty
# rule (real call-site analysis over every module under src/, not a
# substring scan of four files) -- see tools/genielint/rules_spine.py and
# tests/test_lint.py::test_executor_sovereignty_at_head.


# ---------------------------------------------------------------------------
# Plan cache: same (engine, layout shape, k, method, use_kernel) -> no retrace
# ---------------------------------------------------------------------------

def _mono_plan(idx: GenieIndex, k: int, method=TopKMethod.CPQ) -> plan_lib.QueryPlan:
    return plan_lib.plan_search(
        idx.engine, k, idx.max_count, layout=plan_lib.Layout.MONOLITHIC,
        part_rows=(idx.stats.n_objects,), method=method,
        use_kernel=idx.use_kernel,
    )


def test_plan_cache_no_retrace_on_repeat():
    """Repeated searches with the same layout shape reuse the compiled
    executable: the per-plan trace counter stays at 1."""
    model, raw, data, queries, mc = _case(Engine.EQ)
    idx = GenieIndex.build(Engine.EQ, raw, max_count=mc, use_kernel=False)
    plan_lib.clear_plan_cache()

    first = idx.search(queries, k=5)
    key = _mono_plan(idx, 5)
    assert plan_lib.trace_count(key) == 1

    again = idx.search(queries, k=5)                       # same shape: cached
    _assert_same(again, first)
    assert plan_lib.trace_count(key) == 1, "same shape re-traced"

    other_queries = raw[:4]                                # same [4, m] shape
    idx.search(other_queries, k=5)
    assert plan_lib.trace_count(key) == 1, "same query shape re-traced"

    idx.search(queries, k=7)                               # new k: new plan
    assert plan_lib.trace_count(key) == 1
    assert plan_lib.trace_count(_mono_plan(idx, 7)) == 1


def test_plan_cache_segmented_and_scan_paths():
    """The host-loop per-part kernels and the scanned multiload executor are
    cached too: a second identical search traces nothing new."""
    model, raw, data, queries, mc = _case(Engine.EQ)
    seg = SegmentedIndex(engine=Engine.EQ, max_count=mc, use_kernel=False)
    for a, b in zip(CUTS, CUTS[1:]):
        seg.add(raw[a:b])
    idx = GenieIndex.build(Engine.EQ, raw, max_count=mc, use_kernel=False)
    plan_lib.clear_plan_cache()

    seg.search(queries, k=5)
    idx.search_multiload(queries, k=5, n_parts=4)
    size_after_first = plan_lib.plan_cache_size()
    traces_after_first = sum(plan_lib._TRACE_COUNTS.values())

    seg.search(queries, k=5)
    idx.search_multiload(queries, k=5, n_parts=4)
    assert plan_lib.plan_cache_size() == size_after_first
    assert sum(plan_lib._TRACE_COUNTS.values()) == traces_after_first, \
        "repeat search re-traced a cached executable"


# ---------------------------------------------------------------------------
# Plan construction: layout validation, pad accounting, describe()
# ---------------------------------------------------------------------------

def test_part_kernels_survive_corpus_growth():
    """Growing a segmented corpus must not re-trace per-part kernels for
    part shapes already compiled: the kernel key is the part shape (+ match,
    clamped k, pad-mask flag), not the whole corpus layout."""
    model, raw, data, queries, mc = _case(Engine.EQ, n=150)
    seg = SegmentedIndex(engine=Engine.EQ, max_count=mc, use_kernel=False)
    plan_lib.clear_plan_cache()
    seg.add(raw[:50])
    first = seg.search(queries, k=5)
    traces = sum(plan_lib._TRACE_COUNTS.values())
    seg.add(raw[50:100])                       # same 50-row seal shape
    seg.add(raw[100:150])
    grown = seg.search(queries, k=5)
    assert sum(plan_lib._TRACE_COUNTS.values()) == traces, \
        "corpus growth re-traced an already-compiled part kernel"
    mono = GenieIndex.build(Engine.EQ, raw, max_count=mc, use_kernel=False)
    _assert_same(grown, mono.search(queries, k=5))
    mono50 = GenieIndex.build(Engine.EQ, raw[:50], max_count=mc, use_kernel=False)
    _assert_same(first, mono50.search(queries, k=5))


def test_plan_cache_is_bounded(monkeypatch):
    """The executable cache evicts FIFO past PLAN_CACHE_CAP instead of
    pinning stale jitted programs forever."""
    model, raw, data, queries, mc = _case(Engine.EQ, n=24)
    monkeypatch.setattr(plan_lib, "PLAN_CACHE_CAP", 3)
    plan_lib.clear_plan_cache()
    idx = GenieIndex.build(Engine.EQ, raw, max_count=mc, use_kernel=False)
    for k in (1, 2, 3, 4, 5):
        idx.search(queries, k=k)
    assert plan_lib.plan_cache_size() <= 3


def test_scan_layout_rejects_ragged_parts():
    """The scanned multiload executor derives offsets as i * part_rows[0];
    ragged parts must be rejected at plan time (host_loop streams them)."""
    with pytest.raises(ValueError, match="uniform part_rows"):
        plan_lib.plan_search(Engine.EQ, 3, 16,
                             layout=plan_lib.Layout.MULTILOAD,
                             part_rows=(3, 50, 48), n_objects=101)
    ok = plan_lib.plan_search(Engine.EQ, 3, 16,
                              layout=plan_lib.Layout.MULTILOAD,
                              part_rows=(3, 50, 48), n_objects=101,
                              host_loop=True)
    assert ok.host_loop


def test_plan_search_validates_layout():
    with pytest.raises(ValueError, match="n_parts"):
        plan_lib.plan_search(Engine.EQ, 3, 16,
                             layout=plan_lib.Layout.MULTILOAD, n_parts=0,
                             n_objects=10)
    with pytest.raises(ValueError, match="part_rows"):
        plan_lib.plan_search(Engine.EQ, 3, 16,
                             layout=plan_lib.Layout.SEGMENTED)
    with pytest.raises(ValueError, match="monolithic"):
        plan_lib.plan_search(Engine.EQ, 3, 16, part_rows=(4, 4))
    with pytest.raises(ValueError, match="positive"):
        plan_lib.plan_search(Engine.EQ, 3, 16,
                             layout=plan_lib.Layout.SEGMENTED, part_rows=(4, 0))


def test_plan_layout_accounting_and_describe():
    plan = plan_lib.plan_search(
        Engine.EQ, 7, 16, layout=plan_lib.Layout.MULTILOAD, n_parts=4,
        n_objects=101, use_kernel=False,
    )
    assert plan.part_rows == (26, 26, 26, 26)
    assert plan.pad_rows == 3 and plan.total_rows == 104
    assert plan.part_k(2) == 2 and plan.part_k(50) == 7
    d = plan.describe()
    assert d["layout"] == "multiload" and d["engine"] == "eq"
    assert d["merge"] == "incremental-pairwise" and d["pad_rows"] == 3

    host = plan_lib.plan_search(
        Engine.EQ, 7, 16, layout=plan_lib.Layout.MULTILOAD,
        part_rows=(3, 50, 48), n_objects=101, host_loop=True, use_kernel=False,
    )
    assert host.describe()["merge"] == "ragged-buffer"
    dist = plan_lib.plan_search(
        Engine.EQ, 7, 16, layout=plan_lib.Layout.DISTRIBUTED, n_objects=101,
        hierarchical=True, mesh_axes=("pod", "data", "model"),
    )
    assert dist.describe()["merge"] == "collective-hierarchical"


def test_pad_and_stack_fills_with_engine_pad():
    model, raw, data, queries, mc = _case(Engine.EQ)
    plan = plan_lib.plan_search(
        Engine.EQ, 7, mc, layout=plan_lib.Layout.MULTILOAD, n_parts=4,
        n_objects=101, use_kernel=False,
    )
    chunks = plan_lib.pad_and_stack(plan, data)
    assert chunks.shape[:2] == (4, 26)
    flat = np.asarray(chunks).reshape(104, -1)
    assert np.array_equal(flat[:101], np.asarray(data))
    assert np.all(flat[101:] == model.pad_value)


# ---------------------------------------------------------------------------
# PACKED signature layout: layout parity, plan-cache keying, describe()
# ---------------------------------------------------------------------------

PACKED_ENGINES = [e for e in ALL_ENGINES if engines.get(e).supports_packed]


@pytest.mark.parametrize("engine", PACKED_ENGINES)
@pytest.mark.parametrize("method", ALL_METHODS)
def test_planner_packed_layout_parity(engine, method):
    """Every single-process layout under signature_layout=PACKED reproduces
    the WIDE sort oracle's ids and counts exactly, for both match paths
    (use_kernel=True is the fused match->count->local-top-k kernel on the
    MONOLITHIC/SEGMENTED layouts)."""
    k = 9
    model, raw, data, queries, mc = _case(engine)
    oracle = cpq.sort_select(
        model.reference(data, model.prepare_queries(queries)),
        SearchParams(k=k, max_count=mc),
    )
    for use_kernel in (False, True):
        idx = GenieIndex.build(engine, raw, max_count=mc, use_kernel=use_kernel,
                               signature_layout="packed")
        seg = SegmentedIndex(engine=engine, max_count=mc, use_kernel=use_kernel,
                             signature_layout=plan_lib.SignatureLayout.PACKED)
        for a, b in zip(CUTS, CUTS[1:]):
            seg.add(raw[a:b])
        seg.compact(max_segments=2)            # packed segments concat cleanly
        results = {
            "monolithic": idx.search(queries, k=k, method=method),
            "segmented": seg.search(queries, k=k, method=method),
            "multiload-scan": idx.search_multiload(queries, k=k, n_parts=4,
                                                   method=method),
            "multiload-host": seg.search_multiload(queries, k=k, method=method),
        }
        for layout, got in results.items():
            _assert_same(got, oracle,
                         f"{engine.value} {method.value} {layout} packed "
                         f"kernel={use_kernel}")


def test_packed_plans_cache_separately_from_wide():
    """WIDE and PACKED plans for the same layout shape are distinct cache
    keys (their executables consume different array formats), and the fused
    kernel only rides the layouts whose rows are physical object ids."""
    mk = lambda layout_name, **kw: plan_lib.plan_search(
        Engine.COSINE, 5, 32, layout=plan_lib.Layout[layout_name],
        use_kernel=True, **kw)
    wide = mk("MONOLITHIC", part_rows=(64,))
    packed = mk("MONOLITHIC", part_rows=(64,), signature_layout="packed")
    assert wide != packed
    assert hash(wide) != hash(packed)
    assert wide.describe()["signature_layout"] == "wide"
    assert packed.describe()["signature_layout"] == "packed"
    assert not wide.describe()["fused_match"]
    assert packed.describe()["fused_match"]

    seg = mk("SEGMENTED", part_rows=(40, 24), signature_layout="packed")
    assert seg.describe()["fused_match"]
    # engine-filled pad rows (multiload stacks, mesh divisibility) are masked
    # by count, which the fused kernel cannot see -> no fusion there
    ml = mk("MULTILOAD", n_parts=4, n_objects=101, signature_layout="packed")
    assert not ml.describe()["fused_match"]
    dist = plan_lib.plan_search(
        Engine.COSINE, 5, 32, layout=plan_lib.Layout.DISTRIBUTED,
        n_objects=101, use_kernel=True, mesh_axes=("data",),
        signature_layout="packed")
    assert not dist.describe()["fused_match"]
    # reference path (use_kernel=False) has no fused kernel either
    ref = plan_lib.plan_search(
        Engine.COSINE, 5, 32, part_rows=(64,), use_kernel=False,
        signature_layout="packed")
    assert not ref.describe()["fused_match"]


def test_packed_plan_rejects_unsupported_engines():
    with pytest.raises(ValueError, match="no packed signature format"):
        plan_lib.plan_search(Engine.EQ, 5, 16, part_rows=(64,),
                             signature_layout="packed")


def test_retrieval_service_rejects_packed_for_wide_only_scheme():
    """Schemes hashing to WIDE-only engines (e2lsh -> EQ) fail at service
    construction, not at the first add()."""
    from repro.serve.retrieval import RetrievalService

    with pytest.raises(ValueError, match="no packed signature format"):
        RetrievalService(embed_fn=lambda x: np.asarray(x), scheme="e2lsh",
                         m_override=16, signature_layout="packed")


def test_retrieval_service_packed_serving_parity(rng):
    """simhash/minhash services sealed PACKED serve identical results to
    WIDE, and index_stats reports the signature footprint win."""
    from repro.serve.retrieval import RetrievalService

    pts = rng.standard_normal((130, 16)).astype(np.float32)
    for scheme in ("simhash", "minhash"):
        svcs = {
            # n_buckets=128: the packed TANIMOTO layout stores uint8 bucket
            # ids, so the minhash rehash domain must be <= 253
            layout: RetrievalService(embed_fn=lambda x: np.asarray(x),
                                     scheme=scheme, m_override=96,
                                     n_buckets=128, signature_layout=layout)
            for layout in ("wide", "packed")
        }
        for svc in svcs.values():
            for a, b in [(0, 30), (30, 37), (37, 90), (90, 130)]:
                svc.add(list(range(a, b)), embeddings=pts[a:b])
        q = pts[88:96] + 0.01
        rw, sw = svcs["wide"].search(None, k=5, embeddings=q)
        rp, sp = svcs["packed"].search(None, k=5, embeddings=q)
        _assert_same(rp, rw, scheme)
        assert np.allclose(sw, sp), scheme
        stats = svcs["packed"].index_stats
        assert stats.signature_layout == "packed"
        assert 0 < stats.bytes_signatures_packed < stats.bytes_signatures_wide
        assert stats.bytes_signatures_packed <= stats.bytes_signatures_wide / 4
        assert svcs["wide"].index_stats.signature_layout == "wide"


# ---------------------------------------------------------------------------
# Distributed layout parity (subprocess: forced multi-device CPU)
# ---------------------------------------------------------------------------

def test_planner_distributed_parity():
    """Every engine x {CPQ, SPQ, SORT} through the DISTRIBUTED layout (flat
    and hierarchical meshes) equals the sort oracle exactly -- the same plan
    executor as single-device, merged collectively."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import cpq, distributed, engines
        from repro.core import plan as plan_lib
        from repro.core.types import SearchParams, TopKMethod
        from repro.launch import mesh as mesh_lib

        meshes = [mesh_lib.make_mesh((2, 4), ('data', 'model')),
                  mesh_lib.make_mesh((2, 2, 2), ('pod', 'data', 'model'))]
        for eng in sorted(engines.available(), key=lambda e: e.value):
            model = engines.get(eng)
            raw, rawq, mc = model.example(np.random.default_rng(0), 128, 4)
            data = model.prepare_data(raw)
            queries = model.prepare_queries(rawq)
            mx = model.resolve_max_count(data, mc)
            want = cpq.sort_select(model.reference(data, queries),
                                   SearchParams(k=7, max_count=mx))
            for mesh in meshes:
                dd = jax.device_put(data, distributed.data_sharding(mesh))
                qq = jax.tree_util.tree_map(
                    lambda x: jax.device_put(x, distributed.replicated(mesh, 2)),
                    queries)
                for method in TopKMethod:
                    for hier in (False, True):
                        plan = plan_lib.plan_search(
                            eng, 7, mx, layout=plan_lib.Layout.DISTRIBUTED,
                            method=method, use_kernel=False, hierarchical=hier,
                            mesh_axes=tuple(mesh.axis_names))
                        res = plan_lib.execute(plan, dd, qq, mesh=mesh)
                        label = (eng.value, tuple(mesh.axis_names),
                                 method.value, hier)
                        assert np.array_equal(np.asarray(res.ids),
                                              np.asarray(want.ids)), label
                        assert np.array_equal(np.asarray(res.counts),
                                              np.asarray(want.counts)), label
        print('planner distributed parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "planner distributed parity OK" in out.stdout


def test_planner_distributed_packed_parity():
    """PACKED x {reference, kernel} through the sharded search step equals
    the WIDE sort oracle: a packed segmented corpus exported by concat_data
    (pad rows filled with the packed pad value, masked via n_objects) and
    packed replicated queries, with the packed match running inside
    shard_map on each shard's local words/bytes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import SegmentedIndex, cpq, distributed, engines
        from repro.core.types import Engine, SearchParams, SignatureLayout
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        for eng in (Engine.COSINE, Engine.TANIMOTO):
            model = engines.get(eng)
            raw, rawq, mc = model.example(np.random.default_rng(0), 130, 4)
            data = model.prepare_data(raw)
            mx = model.resolve_max_count(data, mc)
            want = cpq.sort_select(model.reference(data, model.prepare_queries(rawq)),
                                   SearchParams(k=7, max_count=mx))
            seg = SegmentedIndex(engine=eng, max_count=mx,
                                 signature_layout=SignatureLayout.PACKED)
            seg.add(raw[:40]); seg.add(raw[40:130])
            pdata, n = seg.concat_data(pad_multiple=mesh.size)
            assert pdata.shape[0] == 136 and n == 130
            dd = jax.device_put(pdata, distributed.data_sharding(mesh))
            qq = jax.device_put(
                model.prepare_queries_for(rawq, SignatureLayout.PACKED),
                distributed.replicated(mesh, 2))
            for use_kernel in (False, True):
                params = SearchParams(k=7, max_count=mx, use_kernel=use_kernel)
                step = distributed.make_search_step(
                    mesh, params, eng, n_objects=n,
                    signature_layout=SignatureLayout.PACKED)
                res = step(dd, qq)
                label = (eng.value, use_kernel)
                assert np.array_equal(np.asarray(res.ids),
                                      np.asarray(want.ids)), label
                assert np.array_equal(np.asarray(res.counts),
                                      np.asarray(want.counts)), label
        print('distributed packed parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "distributed packed parity OK" in out.stdout


def test_retrieval_service_sharded_serving_parity():
    """RetrievalService(mesh=...) serves a segmented corpus sharded across 8
    devices with ids/counts/sims identical to the single-device service, and
    the sharded placement cache refreshes when the corpus changes."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.launch import mesh as mesh_lib
        from repro.serve.retrieval import RetrievalService

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        rng = np.random.default_rng(0)
        pts = rng.standard_normal((130, 16)).astype(np.float32)
        for scheme in ('e2lsh', 'simhash', 'minhash'):
            single = RetrievalService(embed_fn=lambda x: np.asarray(x),
                                      scheme=scheme, m_override=96)
            sharded = RetrievalService(embed_fn=lambda x: np.asarray(x),
                                       scheme=scheme, m_override=96, mesh=mesh)
            for a, b in [(0, 30), (30, 37), (37, 90), (90, 130)]:
                single.add(list(range(a, b)), embeddings=pts[a:b])
                sharded.add(list(range(a, b)), embeddings=pts[a:b])
            q = pts[88:96] + 0.01
            r1, s1 = single.search(None, k=5, embeddings=q)
            r2, s2 = sharded.search(None, k=5, embeddings=q)
            assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids)), scheme
            assert np.array_equal(np.asarray(r1.counts),
                                  np.asarray(r2.counts)), scheme
            assert np.allclose(s1, s2), scheme
            placed = sharded._placed
            sharded.search(None, k=5, embeddings=q)
            assert sharded._placed is placed, 'placement not cached'
            sharded.add([999], embeddings=pts[:1])
            sharded.search(None, k=5, embeddings=q)
            assert sharded._placed is not placed, 'placement not refreshed'
            assert sharded.items_for(np.asarray(r2.ids))[0][0] is not None
        print('sharded serving parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "sharded serving parity OK" in out.stdout


# ---------------------------------------------------------------------------
# Serving-layer satellites: clear errors for empty service / bad ids
# ---------------------------------------------------------------------------

def test_retrieval_service_empty_search_names_service_state():
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    with pytest.raises(ValueError, match="RetrievalService.*empty.*add"):
        svc.search(None, k=3, embeddings=np.zeros((1, 8), np.float32))
    with pytest.raises(ValueError, match="RetrievalService.*empty.*add"):
        svc.index_stats


def test_retrieval_service_items_for_validates_ids(rng):
    from repro.serve.retrieval import RetrievalService

    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=16)
    svc.add([10, 11, 12], embeddings=rng.standard_normal((3, 8)).astype(np.float32))
    assert svc.items_for(np.asarray([[0, 2, -1]])) == [[10, 12, None]]
    with pytest.raises(ValueError, match="3 items.*0..2|id 3"):
        svc.items_for(np.asarray([[0, 3]]))
    with pytest.raises(ValueError, match="id -5"):
        svc.items_for(np.asarray([[-5]]))
