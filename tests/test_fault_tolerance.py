"""Fault-tolerance runtime: heartbeats, stragglers, restart policy, elastic
mesh planning, and end-to-end crash recovery through the Trainer."""
import numpy as np
import pytest

from repro.runtime import (
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
    elastic_mesh_shape,
)


def test_heartbeat_liveness():
    hb = HeartbeatMonitor(n_hosts=4, timeout_s=10)
    for h in range(3):
        hb.beat(h, now=100.0)
    assert hb.alive(now=105.0) == [0, 1, 2]
    assert hb.dead(now=105.0) == [3]
    assert hb.alive(now=120.0) == []


def test_straggler_detection():
    sd = StragglerDetector(n_hosts=4, ratio=1.5, min_samples=3)
    for step in range(6):
        for h in range(4):
            sd.record(h, 1.0 if h != 2 else 3.0)
    assert sd.stragglers() == [2]
    assert 0.9 < sd.median() < 1.1


def test_restart_policy_budget():
    rp = RestartPolicy(max_restarts=3, backoff_base_s=1.0)
    delays = [rp.on_failure() for _ in range(3)]
    assert delays == [1.0, 2.0, 4.0]
    with pytest.raises(RuntimeError):
        rp.on_failure()
    rp.on_success_window()
    assert rp.on_failure() == 4.0  # forgiveness freed one slot


def test_elastic_mesh_shape():
    # full fleet: 128 hosts x 4 chips = 512 = 2 pods of 256
    assert elastic_mesh_shape(128, 4, model_parallel=16) == (2, 16, 16)
    # lose a pod's worth: single-pod mesh
    assert elastic_mesh_shape(64, 4, model_parallel=16) == (16, 16)
    # odd fleet shrinks the data axis
    assert elastic_mesh_shape(60, 4, model_parallel=16) == (15, 16)
    # not enough for TP
    assert elastic_mesh_shape(2, 4, model_parallel=16) == ()


def test_trainer_recovers_from_injected_failures(tmp_path):
    from repro.data.pipeline import DataConfig
    from repro.models.registry import get_api, get_config
    from repro.optim.adamw import AdamWConfig
    from repro.train import Trainer, TrainerConfig, TrainHParams

    crashes = {"left": 2}

    def injector(step):
        if step == 7 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise RuntimeError("injected node failure")

    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    hp = TrainHParams(optimizer=AdamWConfig(lr=1e-3), total_steps=12, warmup_steps=2)
    tc = TrainerConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=5,
                       log_every=4, async_checkpoint=False)
    tr = Trainer(cfg, api, hp, tc, DataConfig(global_batch=2, seq_len=32),
                 fail_injector=injector)
    hist = tr.run()
    assert crashes["left"] == 0           # both failures fired
    assert hist[-1]["step"] == 12         # training still completed
    assert np.isfinite(hist[-1]["loss"])
    assert tr.recoveries == 2             # both injected failures survived
