"""Packed signature storage (core/packing.py) + fused kernels: unit sweeps.

The layout contract: packing is storage-only.  Counts, ids, and candidate
buffers computed on packed arrays are bit-for-bit equal to the WIDE
references for every signature width -- including widths that don't divide
the 32-bit word (COSINE tail bits) and tile sizes that don't divide N/Q
(kernel grid padding).  System-level parity (engine x layout x method) lives
in tests/test_engine_matrix.py and tests/test_plan.py; this module pins the
packing primitives and the Pallas kernels themselves.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cpq, engines, match, packing
from repro.core.types import Engine, SearchParams
from repro.kernels import ops


def _signs(rng, n, v):
    return (rng.integers(0, 2, (n, v)) * 2 - 1).astype(np.int8)


# ---------------------------------------------------------------------------
# Bit-packing round trip + tail-bit convention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v", [1, 31, 32, 33, 64, 513])
def test_pack_signs_round_trip(v):
    rng = np.random.default_rng(v)
    signs = _signs(rng, 9, v)
    words = packing.pack_signs_data(jnp.asarray(signs))
    assert words.shape == (9, packing.packed_words(v))
    assert words.dtype == jnp.int32
    back = np.asarray(packing.unpack_signs(words, v))
    assert np.array_equal(back, signs)


@pytest.mark.parametrize("v", [1, 31, 33, 95])
def test_packed_cosine_tail_bits_exact(v):
    """Data tail bits 0 vs query tail bits 1: every tail bit disagrees, so
    agreements = 32W - popcount(xor) without storing V in the words."""
    rng = np.random.default_rng(v)
    d, q = _signs(rng, 13, v), _signs(rng, 3, v)
    want = np.asarray(match.match_cosine(jnp.asarray(d), jnp.asarray(q)))
    got = np.asarray(packing.packed_cosine_match(
        packing.pack_signs_data(jnp.asarray(d)),
        packing.pack_signs_queries(jnp.asarray(q))))
    assert np.array_equal(got, want)


def test_pack_buckets_domain_validation():
    ok = jnp.asarray([[0, 253], [7, 100]], dtype=jnp.int32)
    packed = packing.pack_buckets(ok)
    assert packed.dtype == jnp.uint8
    for bad in ([[254]], [[255]], [[-1]]):
        with pytest.raises(ValueError, match="bucket"):
            packing.pack_buckets(jnp.asarray(bad, dtype=jnp.int32))


def test_packed_tanimoto_reference_matches_wide():
    rng = np.random.default_rng(0)
    d = rng.integers(0, 200, (17, 9)).astype(np.int32)
    q = rng.integers(0, 200, (4, 9)).astype(np.int32)
    want = np.asarray(match.match_tanimoto(jnp.asarray(d), jnp.asarray(q)))
    got = np.asarray(packing.packed_tanimoto_match(
        packing.pack_buckets(jnp.asarray(d)),
        packing.pack_buckets(jnp.asarray(q))))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# Pallas count kernels (interpret mode on CPU) vs wide reference counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,q,v", [(7, 3, 33), (130, 5, 64), (64, 4, 513)])
def test_packed_cosine_count_kernel(n, q, v):
    rng = np.random.default_rng(n * v)
    d, s = _signs(rng, n, v), _signs(rng, q, v)
    want = np.asarray(match.match_cosine(jnp.asarray(d), jnp.asarray(s)))
    got = np.asarray(ops.packed_cosine_count(
        packing.pack_signs_data(jnp.asarray(d)),
        packing.pack_signs_queries(jnp.asarray(s))))
    assert got.dtype == np.int32
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,q,m", [(7, 3, 5), (130, 5, 17), (64, 4, 40)])
def test_packed_tanimoto_count_kernel(n, q, m):
    rng = np.random.default_rng(n * m)
    d = rng.integers(0, 250, (n, m)).astype(np.int32)
    s = rng.integers(0, 250, (q, m)).astype(np.int32)
    want = np.asarray(match.match_tanimoto(jnp.asarray(d), jnp.asarray(s)))
    got = np.asarray(ops.packed_tanimoto_count(
        packing.pack_buckets(jnp.asarray(d)),
        packing.pack_buckets(jnp.asarray(s))))
    assert got.dtype == np.int32
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# Fused match->count->local-top-k kernels: candidate buffers hold the top-k
# ---------------------------------------------------------------------------

def _assert_candidates_cover_topk(ids, cnts, counts_ref, k, n):
    """The fused kernel's [Q, n_tiles*kc] buffers, merged by
    topk_from_candidates, must equal the sort oracle exactly."""
    got = cpq.topk_from_candidates(jnp.asarray(ids), jnp.asarray(cnts),
                                   min(k, ids.shape[1]))
    want = cpq.sort_select(jnp.asarray(counts_ref),
                           SearchParams(k=k, max_count=int(counts_ref.max()) + 1))
    kk = min(k, got[0].shape[1])
    assert np.array_equal(np.asarray(got[0])[:, :kk],
                          np.asarray(want.ids)[:, :kk])
    assert np.array_equal(np.asarray(got[1])[:, :kk],
                          np.asarray(want.counts)[:, :kk])
    # physical pad rows (>= n) may never appear in any candidate slot
    assert np.asarray(ids).max() < n


@pytest.mark.parametrize("n,q,v,k", [(7, 3, 33, 3), (130, 5, 64, 10),
                                     (300, 4, 95, 7)])
def test_packed_cosine_fused_topk(n, q, v, k):
    rng = np.random.default_rng(n + v)
    d, s = _signs(rng, n, v), _signs(rng, q, v)
    counts = np.asarray(match.match_cosine(jnp.asarray(d), jnp.asarray(s)))
    ids, cnts = ops.packed_cosine_topk(
        packing.pack_signs_data(jnp.asarray(d)),
        packing.pack_signs_queries(jnp.asarray(s)), k=k)
    _assert_candidates_cover_topk(ids, cnts, counts, k, n)


@pytest.mark.parametrize("n,q,m,k", [(7, 3, 5, 3), (130, 5, 17, 10)])
def test_packed_tanimoto_fused_topk(n, q, m, k):
    rng = np.random.default_rng(n + m)
    d = rng.integers(0, 250, (n, m)).astype(np.int32)
    s = rng.integers(0, 250, (q, m)).astype(np.int32)
    counts = np.asarray(match.match_tanimoto(jnp.asarray(d), jnp.asarray(s)))
    ids, cnts = ops.packed_tanimoto_topk(
        packing.pack_buckets(jnp.asarray(d)),
        packing.pack_buckets(jnp.asarray(s)), k=k)
    _assert_candidates_cover_topk(ids, cnts, counts, k, n)


def test_fused_tie_break_is_count_desc_id_asc():
    """All-equal counts: the fused buffers must surface the lowest ids so the
    merged ordering matches every other selection path."""
    d = jnp.ones((40, 8), dtype=jnp.int8)          # identical sign rows
    s = jnp.ones((2, 8), dtype=jnp.int8)
    ids, cnts = ops.packed_cosine_topk(
        packing.pack_signs_data(d), packing.pack_signs_queries(s), k=5)
    got_ids, got_cnts = cpq.topk_from_candidates(ids, cnts, 5)
    assert np.array_equal(np.asarray(got_ids),
                          np.tile(np.arange(5, dtype=np.int32), (2, 1)))
    assert np.all(np.asarray(got_cnts) == 8)


# ---------------------------------------------------------------------------
# Engine-registry integration: tiny-corpus fill, storage accounting
# ---------------------------------------------------------------------------

def test_packed_search_tiny_corpus_fills_missing_slots():
    """n < k: the packed fused path pads its candidate buffer to k columns
    with (-1, -1), exactly like the wide selector's empty slots."""
    from repro.core import GenieIndex

    rng = np.random.default_rng(3)
    raw = rng.standard_normal((3, 16)).astype(np.float32)
    q = rng.standard_normal((2, 16)).astype(np.float32)
    wide = GenieIndex.build_cosine(raw).search(q, k=8)
    packed = GenieIndex.build_cosine(raw, signature_layout="packed").search(q, k=8)
    assert np.array_equal(np.asarray(packed.ids), np.asarray(wide.ids))
    assert np.array_equal(np.asarray(packed.counts), np.asarray(wide.counts))
    assert np.all(np.asarray(packed.ids)[:, 3:] == -1)


def test_build_stats_report_signature_footprint():
    rng = np.random.default_rng(0)
    raw = rng.standard_normal((64, 256)).astype(np.float32)
    model = engines.get(Engine.COSINE)
    stats = model.build_stats(model.prepare_data(raw))
    assert stats.bytes_signatures_wide == 64 * 256          # int8 signs
    assert stats.bytes_signatures_packed == 64 * 8 * 4      # 8 words/row
    assert stats.bytes_signatures_packed * 8 == stats.bytes_signatures_wide

    sk = rng.integers(0, 64, (64, 20)).astype(np.int32)
    tstats = engines.get(Engine.TANIMOTO).build_stats(jnp.asarray(sk))
    assert tstats.bytes_signatures_wide == 64 * 20 * 4
    assert tstats.bytes_signatures_packed == 64 * 20        # uint8 buckets
