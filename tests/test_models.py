"""Per-architecture smoke tests (reduced configs, deliverable f) and
decode/forward consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import layers as L
from repro.models.registry import get_api, get_config, list_archs
from repro.optim.adamw import AdamWConfig
from repro.train import step as tsl

SMOKE_ARCHS = [a for a in list_archs() if a.endswith("-smoke")]
assert len(SMOKE_ARCHS) == 10


@pytest.mark.parametrize("arch", SMOKE_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg = get_config(arch)
    api = get_api(cfg)
    hp = tsl.TrainHParams(optimizer=AdamWConfig(lr=1e-3), total_steps=2, warmup_steps=1)
    state = tsl.init_state(cfg, api, jax.random.PRNGKey(0), hp)
    batch = SyntheticTokens(cfg, DataConfig(global_batch=2, seq_len=32)).batch(0)

    logits, aux, labels = api.train_logits(cfg, state.params, batch, remat=False)
    b = batch["tokens"].shape[0]
    s_total = logits.shape[1]
    assert logits.shape == (b, s_total, cfg.vocab)
    assert labels.shape == (b, s_total)
    assert not bool(jnp.isnan(logits).any())

    step = jax.jit(tsl.make_train_step(cfg, api, hp), donate_argnums=(0,))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", [
    "phi3-mini-3.8b-smoke", "mamba2-1.3b-smoke", "zamba2-2.7b-smoke",
    "qwen2-moe-a2.7b-smoke", "internvl2-76b-smoke", "seamless-m4t-large-v2-smoke",
])
def test_decode_matches_forward(arch):
    """prefill + decode_step logits == teacher-forced forward at that position."""
    import dataclasses

    cfg = get_config(arch)
    if cfg.family == "moe":  # capacity dropping is population-dependent
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, DataConfig(global_batch=2, seq_len=16)).batch(0)

    last, cache, pos = api.prefill(cfg, params, batch, cache_cap=32)
    nt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    step_logits, _ = api.decode_step(cfg, params, nt, cache, pos)

    batch2 = dict(batch)
    toks = jnp.asarray(batch["tokens"])
    pad = jnp.zeros((toks.shape[0], 7), jnp.int32)  # pad to ssd-chunk multiple
    batch2["tokens"] = jnp.concatenate([toks, nt, pad], axis=1)
    if cfg.family == "audio":
        f = jnp.asarray(batch["frames"])
        batch2["frames"] = f
    full_logits, _, _ = api.train_logits(cfg, params, batch2, remat=False)
    at = full_logits.shape[1] - 8 - (0 if cfg.family != "vlm" else 0)
    pos_idx = int(np.asarray(pos)) if cfg.family != "vlm" else toks.shape[1] + cfg.n_patches
    want = full_logits[:, pos_idx, :] if cfg.family == "vlm" else full_logits[:, at, :]
    err = float(jnp.abs(step_logits - want).max())
    assert err < 5e-2, (arch, err)


def test_chunked_attention_exact():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 1024, 4, 16))
    k = jax.random.normal(ks[1], (2, 1024, 2, 16))
    v = jax.random.normal(ks[2], (2, 1024, 2, 16))
    for causal in (True, False):
        a = L.chunked_attention(q, k, v, causal=causal, q_chunk=256, k_chunk=512)
        b = L.full_attention(q, k, v, causal=causal)
        assert float(jnp.abs(a - b).max()) < 1e-5


def test_attention_softcap():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 8, 2, 8)) * 10
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 8)) * 10
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 2, 8))
    a = L.full_attention(q, k, v, causal=True, softcap=30.0)
    assert not bool(jnp.isnan(a).any())


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are within 10% of the published sizes."""
    expect = {
        "phi3-mini-3.8b": 3.8e9, "mistral-large-123b": 123e9, "qwen2.5-14b": 14.8e9,
        "smollm-360m": 0.36e9, "mamba2-1.3b": 1.3e9, "qwen2-moe-a2.7b": 14.3e9,
        "grok-1-314b": 314e9, "internvl2-76b": 70e9,
    }
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)


def test_moe_active_params_fraction():
    cfg = get_config("qwen2-moe-a2.7b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
