"""c-PQ exactness (paper Theorem 3.1) and selection-method agreement.

Formerly hypothesis property tests; rewritten as seeded-random parametrized
cases so the tier-1 suite runs on environments without hypothesis (same
coverage: each case draws its shape/k/max_count from an independent seed).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cpq, merge, spq
from repro.core.types import SearchParams


def _sorted_counts(counts, k):
    return np.sort(counts, axis=1)[:, ::-1][:, :k]


@pytest.mark.parametrize("case", range(25))
def test_cpq_matches_sort_topk(case):
    draw = np.random.default_rng(1000 + case)
    q = int(draw.integers(1, 5))
    n = int(draw.integers(1, 201))
    mx = int(draw.integers(1, 41))
    k = int(draw.integers(1, 21))
    counts = draw.integers(0, mx + 1, size=(q, n)).astype(np.int32)
    p = SearchParams(k=k, max_count=mx)
    res = cpq.cpq_select(jnp.asarray(counts), p)
    want = _sorted_counts(counts, k)
    got = np.asarray(res.counts)
    kk = min(k, n)
    assert np.array_equal(got[:, :kk], want[:, :kk])
    if n < k:  # padding contract
        assert np.all(got[:, n:] == -1)


@pytest.mark.parametrize("case", range(25))
def test_threshold_is_kth_count(case):
    """Theorem 3.1: AT - 1 == MC_k (count of the k-th object)."""
    draw = np.random.default_rng(2000 + case)
    n = int(draw.integers(1, 301))
    mx = int(draw.integers(1, 31))
    k = int(draw.integers(1, 11))
    counts = draw.integers(0, mx + 1, size=(2, n)).astype(np.int32)
    p = SearchParams(k=k, max_count=mx)
    res = cpq.cpq_select(jnp.asarray(counts), p)
    if n >= k:
        kth = np.sort(counts, axis=1)[:, ::-1][:, k - 1]
        assert np.array_equal(np.asarray(res.threshold), kth)


def test_returned_ids_have_returned_counts(rng):
    counts = rng.integers(0, 20, size=(3, 500)).astype(np.int32)
    p = SearchParams(k=9, max_count=20)
    res = cpq.cpq_select(jnp.asarray(counts), p)
    ids, vals = np.asarray(res.ids), np.asarray(res.counts)
    for qi in range(3):
        assert np.array_equal(counts[qi, ids[qi]], vals[qi])
        # non-increasing
        assert np.all(np.diff(vals[qi]) <= 0)


@pytest.mark.parametrize("case", range(15))
def test_spq_matches_sort(case):
    draw = np.random.default_rng(3000 + case)
    n = int(draw.integers(2, 201))
    mx = int(draw.integers(1, 26))
    k = int(draw.integers(1, 13))
    counts = draw.integers(0, mx + 1, size=(2, n)).astype(np.int32)
    p = SearchParams(k=k, max_count=mx)
    res = spq.spq_select(jnp.asarray(counts), p)
    want = _sorted_counts(counts, min(k, n))
    assert np.array_equal(np.asarray(res.counts)[:, : min(k, n)], want)


def test_gate_audit_threshold_properties(rng):
    """ZA[AT] < k <= ZA[AT-1] (Lemma 3.1)."""
    counts = rng.integers(0, 15, size=(4, 300)).astype(np.int32)
    hist = cpq.count_histogram(jnp.asarray(counts), 15)
    za = np.asarray(cpq.zipper_array(hist))
    at, thr = cpq.audit_threshold(hist, 7)
    at = np.asarray(at)
    for qi in range(4):
        if at[qi] <= 15:
            assert za[qi, at[qi]] < 7
        assert za[qi, at[qi] - 1] >= 7


@pytest.mark.parametrize("case", range(15))
def test_merge_equals_global_topk(case):
    """Merging per-part top-k == top-k of the union (multiload correctness)."""
    draw = np.random.default_rng(4000 + case)
    parts = int(draw.integers(1, 6))
    n_per = int(draw.integers(1, 61))
    k = int(draw.integers(1, 9))
    q = 3
    all_counts = draw.integers(0, 30, size=(q, parts * n_per)).astype(np.int32)
    per_ids, per_counts = [], []
    for pi in range(parts):
        seg = all_counts[:, pi * n_per : (pi + 1) * n_per]
        p = SearchParams(k=k, max_count=30)
        r = cpq.cpq_select(jnp.asarray(seg), p)
        per_ids.append(np.where(np.asarray(r.ids) >= 0, np.asarray(r.ids) + pi * n_per, -1))
        per_counts.append(np.asarray(r.counts))
    res = merge.merge_topk(jnp.asarray(np.stack(per_ids)), jnp.asarray(np.stack(per_counts)), k)
    kk = min(k, parts * n_per)
    want = _sorted_counts(all_counts, kk)
    assert np.array_equal(np.asarray(res.counts)[:, :kk], want)
    # tree merge agrees
    res2 = merge.tree_merge(jnp.asarray(np.stack(per_ids)), jnp.asarray(np.stack(per_counts)), k)
    assert np.array_equal(np.asarray(res.counts), np.asarray(res2.counts))
