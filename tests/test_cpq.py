"""c-PQ exactness (paper Theorem 3.1) and selection-method agreement."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cpq, merge, spq
from repro.core.types import SearchParams


def _sorted_counts(counts, k):
    return np.sort(counts, axis=1)[:, ::-1][:, :k]


@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 4),
    n=st.integers(1, 200),
    mx=st.integers(1, 40),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_cpq_matches_sort_topk(q, n, mx, k, seed):
    counts = np.random.default_rng(seed).integers(0, mx + 1, size=(q, n)).astype(np.int32)
    p = SearchParams(k=k, max_count=mx)
    res = cpq.cpq_select(jnp.asarray(counts), p)
    want = _sorted_counts(counts, k)
    got = np.asarray(res.counts)
    kk = min(k, n)
    assert np.array_equal(got[:, :kk], want[:, :kk])
    if n < k:  # padding contract
        assert np.all(got[:, n:] == -1)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    mx=st.integers(1, 30),
    k=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_threshold_is_kth_count(n, mx, k, seed):
    """Theorem 3.1: AT - 1 == MC_k (count of the k-th object)."""
    counts = np.random.default_rng(seed).integers(0, mx + 1, size=(2, n)).astype(np.int32)
    p = SearchParams(k=k, max_count=mx)
    res = cpq.cpq_select(jnp.asarray(counts), p)
    if n >= k:
        kth = np.sort(counts, axis=1)[:, ::-1][:, k - 1]
        assert np.array_equal(np.asarray(res.threshold), kth)


def test_returned_ids_have_returned_counts(rng):
    counts = rng.integers(0, 20, size=(3, 500)).astype(np.int32)
    p = SearchParams(k=9, max_count=20)
    res = cpq.cpq_select(jnp.asarray(counts), p)
    ids, vals = np.asarray(res.ids), np.asarray(res.counts)
    for qi in range(3):
        assert np.array_equal(counts[qi, ids[qi]], vals[qi])
        # non-increasing
        assert np.all(np.diff(vals[qi]) <= 0)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 200),
    mx=st.integers(1, 25),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_spq_matches_sort(n, mx, k, seed):
    counts = np.random.default_rng(seed).integers(0, mx + 1, size=(2, n)).astype(np.int32)
    p = SearchParams(k=k, max_count=mx)
    res = spq.spq_select(jnp.asarray(counts), p)
    want = _sorted_counts(counts, min(k, n))
    assert np.array_equal(np.asarray(res.counts)[:, : min(k, n)], want)


def test_gate_audit_threshold_properties(rng):
    """ZA[AT] < k <= ZA[AT-1] (Lemma 3.1)."""
    counts = rng.integers(0, 15, size=(4, 300)).astype(np.int32)
    hist = cpq.count_histogram(jnp.asarray(counts), 15)
    za = np.asarray(cpq.zipper_array(hist))
    at, thr = cpq.audit_threshold(hist, 7)
    at = np.asarray(at)
    for qi in range(4):
        if at[qi] <= 15:
            assert za[qi, at[qi]] < 7
        assert za[qi, at[qi] - 1] >= 7


@settings(max_examples=15, deadline=None)
@given(
    parts=st.integers(1, 5),
    n_per=st.integers(1, 60),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_merge_equals_global_topk(parts, n_per, k, seed):
    """Merging per-part top-k == top-k of the union (multiload correctness)."""
    rng = np.random.default_rng(seed)
    q = 3
    all_counts = rng.integers(0, 30, size=(q, parts * n_per)).astype(np.int32)
    per_ids, per_counts = [], []
    for pi in range(parts):
        seg = all_counts[:, pi * n_per : (pi + 1) * n_per]
        p = SearchParams(k=k, max_count=30)
        r = cpq.cpq_select(jnp.asarray(seg), p)
        per_ids.append(np.where(np.asarray(r.ids) >= 0, np.asarray(r.ids) + pi * n_per, -1))
        per_counts.append(np.asarray(r.counts))
    res = merge.merge_topk(jnp.asarray(np.stack(per_ids)), jnp.asarray(np.stack(per_counts)), k)
    kk = min(k, parts * n_per)
    want = _sorted_counts(all_counts, kk)
    assert np.array_equal(np.asarray(res.counts)[:, :kk], want)
    # tree merge agrees
    res2 = merge.tree_merge(jnp.asarray(np.stack(per_ids)), jnp.asarray(np.stack(per_counts)), k)
    assert np.array_equal(np.asarray(res.counts), np.asarray(res2.counts))
