"""SSD (Mamba2) correctness: chunked scan == naive recurrence; decode
continuation; conv state handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


def _ssd_ref(x, dt, A_log, B, C):
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    S = np.zeros((b, h, n, p))
    ys = np.zeros((b, s, h, p))
    a = -np.exp(np.asarray(A_log, np.float64)) * np.asarray(dt, np.float64)
    Bh = np.repeat(np.asarray(B, np.float64), hg, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), hg, axis=2)
    xd = np.asarray(x, np.float64) * np.asarray(dt, np.float64)[..., None]
    for t in range(s):
        S = S * np.exp(a[:, t])[..., None, None] + Bh[:, t][..., None] * xd[:, t][:, :, None, :]
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], S)
    return ys, S


@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_chunked_matches_recurrence(chunk, g, rng):
    b, s, h, p, n = 2, 32, 4, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
    A_log = jnp.asarray(rng.standard_normal(h) * 0.5, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    y, S = ssm.ssd_chunked(x, dt, A_log, B, C, chunk=chunk)
    yr, Sr = _ssd_ref(x, dt, A_log, B, C)
    assert np.abs(np.asarray(y) - yr).max() < 1e-4
    assert np.abs(np.asarray(S) - Sr).max() < 1e-4


def test_ssd_decode_continues_chunked_state(rng):
    b, s, h, p, g, n = 1, 24, 2, 4, 1, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
    A_log = jnp.asarray(rng.standard_normal(h) * 0.5, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    _, S16 = ssm.ssd_chunked(x[:, :16], dt[:, :16], A_log, B[:, :16], C[:, :16], chunk=8)
    Sd = S16
    for t in range(16, 24):
        yd, Sd = ssm.ssd_decode(x[:, t], dt[:, t], A_log, B[:, t], C[:, t], Sd)
    _, Sfull = ssm.ssd_chunked(x, dt, A_log, B, C, chunk=8)
    assert np.abs(np.asarray(Sd) - np.asarray(Sfull)).max() < 1e-4
    # y at final step matches a one-shot run's implied output
    yr, _ = _ssd_ref(x, dt, A_log, B, C)
    assert np.abs(np.asarray(yd) - yr[:, -1]).max() < 1e-4


def test_ssd_init_state_resume(rng):
    """ssd_chunked(init_state=S) == continuing the same sequence."""
    b, s, h, p, g, n = 1, 32, 2, 4, 1, 8
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
    A_log = jnp.asarray(rng.standard_normal(h) * 0.5, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    y_full, S_full = ssm.ssd_chunked(x, dt, A_log, B, C, chunk=8)
    _, S_half = ssm.ssd_chunked(x[:, :16], dt[:, :16], A_log, B[:, :16], C[:, :16], chunk=8)
    y2, S2 = ssm.ssd_chunked(
        x[:, 16:], dt[:, 16:], A_log, B[:, 16:], C[:, 16:], chunk=8, init_state=S_half
    )
    assert np.abs(np.asarray(S2) - np.asarray(S_full)).max() < 1e-4
    assert np.abs(np.asarray(y2) - np.asarray(y_full[:, 16:])).max() < 1e-4


def test_causal_conv_matches_decode(rng):
    b, s, ch, w = 2, 10, 6, 4
    xbc = jnp.asarray(rng.standard_normal((b, s, ch)), jnp.float32)
    wgt = jnp.asarray(rng.standard_normal((w, ch)) * 0.5, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(ch) * 0.1, jnp.float32)
    full = ssm.causal_conv(xbc, wgt, bias)
    # replay step-by-step
    state = jnp.zeros((b, w - 1, ch))
    for t in range(s):
        y, state = ssm.conv_decode(xbc[:, t], state, wgt, bias)
        assert np.abs(np.asarray(y) - np.asarray(full[:, t])).max() < 1e-5
