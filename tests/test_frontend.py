"""Serving front-end conformance: coalesced multi-tenant results must be
bit-for-bit identical to serial per-request `search` across every engine,
routed and unrouted, and the queue/admission/drain machinery must behave
deterministically.

The load-bearing invariant: a coalesced dispatch stacks the query rows of
several requests and runs at the shared bucketed k; each request's result is
a row-slice and k-prefix of that dispatch.  Because every engine's result
order is total ((count desc, id asc)) and per-query independent, the slice
equals the serial per-request search exactly -- ids, counts, thresholds,
sims.  Routing='routed_verified' keeps the guarantee (it is bit-for-bit
equal to the full scan by construction); plain 'routed' is batch-dependent
by contract and is excluded from the bit-exactness matrix.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core import Engine, TopKMethod
from repro.core import plan as plan_lib
from repro.core.engines import get as get_model
from repro.core.routing import Routing
from repro.core.segments import SegmentedIndex
from repro.serve import (IndexService, Overloaded, RetrievalService,
                         ServingFrontend)
from repro.serve.metrics import FrontendMetrics, percentile
from repro.serve.scheduler import Request, RequestQueue, coalesce

ENGINES = [Engine.EQ, Engine.RANGE, Engine.MINSUM, Engine.IP,
           Engine.TANIMOTO, Engine.COSINE]
SEG_ROWS = (40, 25, 17)


def _example(engine: Engine, n: int, q: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return get_model(engine).example(rng, n, q)


def _build_index(engine: Engine, seed: int = 0) -> tuple[SegmentedIndex, object]:
    """A 3-uneven-segment index plus a query batch, reference-path (fast)."""
    data, queries, max_count = _example(engine, sum(SEG_ROWS), 16, seed)
    idx = SegmentedIndex(engine=engine, max_count=max_count, use_kernel=False)
    lo = 0
    for rows in SEG_ROWS:
        idx.add(data[lo:lo + rows])
        lo += rows
    return idx, queries


def _stackable(engine: Engine, queries):
    """Queries as one array with axis 0 = query rows (RANGE's (lo, hi) pair
    stacks to [q, 2, d]), plus the adapter back to the engine's form."""
    if engine is Engine.RANGE:
        return (np.stack([np.asarray(queries[0]), np.asarray(queries[1])],
                         axis=1),
                lambda a: (a[:, 0, :], a[:, 1, :]))
    return np.asarray(queries), None


def _assert_result_equal(ref, refsims, got, gotsims, ctx=""):
    assert np.array_equal(np.asarray(ref.ids), np.asarray(got.ids)), ctx
    assert np.array_equal(np.asarray(ref.counts), np.asarray(got.counts)), ctx
    assert np.array_equal(np.asarray(ref.threshold),
                          np.asarray(got.threshold)), ctx
    if refsims is None:
        assert gotsims is None, ctx
    else:
        assert np.array_equal(np.asarray(refsims), np.asarray(gotsims)), ctx


# ---------------------------------------------------------------------------
# core/plan: the batch-compatibility key
# ---------------------------------------------------------------------------

def test_k_bucket_rounds_up_to_power_of_two():
    assert [plan_lib.k_bucket(k) for k in (1, 2, 3, 4, 5, 8, 9, 16, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 16, 32]
    with pytest.raises(ValueError, match="k must be"):
        plan_lib.k_bucket(0)


def test_batch_compat_key_axes():
    base = plan_lib.batch_compat_key(Engine.EQ, "segmented", "wide", "none",
                                     TopKMethod.CPQ, 10)
    # k=10 and k=16 share the 16-bucket; k=17 does not
    assert base == plan_lib.batch_compat_key(Engine.EQ, "segmented", "wide",
                                             "none", TopKMethod.CPQ, 16)
    for kw in (dict(k=17), dict(method=TopKMethod.SORT),
               dict(routing="routed_verified"), dict(engine=Engine.COSINE),
               dict(layout="distributed"), dict(nprobe=2),
               dict(candidate_cap=32)):
        args = dict(engine=Engine.EQ, layout="segmented",
                    signature_layout="wide", routing="none",
                    method=TopKMethod.CPQ, k=10)
        extra = {k: v for k, v in kw.items() if k in ("nprobe", "candidate_cap")}
        args.update({k: v for k, v in kw.items() if k not in extra})
        assert plan_lib.batch_compat_key(**args, **extra) != base, kw
    # an explicit candidate_cap pins exact k (no bucketing): k=10 != k=16
    assert plan_lib.batch_compat_key(
        Engine.EQ, "segmented", "wide", "none", TopKMethod.CPQ, 10,
        candidate_cap=32,
    ) != plan_lib.batch_compat_key(
        Engine.EQ, "segmented", "wide", "none", TopKMethod.CPQ, 16,
        candidate_cap=32)


# ---------------------------------------------------------------------------
# scheduler: coalescing + admission
# ---------------------------------------------------------------------------

def _req(seq, tenant, q, key, k=4):
    return Request(seq=seq, tenant=tenant, embeddings=np.zeros((q, 3)),
                   k=k, dispatch_k=plan_lib.k_bucket(k),
                   method=TopKMethod.CPQ, routing=Routing.NONE, nprobe=None,
                   candidate_cap=None, key=(tenant, key), future=Future(),
                   submitted_at=time.perf_counter())


def test_coalesce_groups_by_key_and_chunks_by_max_batch():
    reqs = [_req(0, "a", 4, "x"), _req(1, "b", 4, "x"), _req(2, "a", 4, "x"),
            _req(3, "a", 4, "y"), _req(4, "a", 9, "x")]
    groups = coalesce(reqs, max_batch=8)
    # (a, x) chunks into [0, 2] then [4] (9 rows alone exceeds the cap but a
    # single request is never split); (b, x) and (a, y) are their own groups
    seqs = [[r.seq for r in g] for g in groups]
    assert seqs == [[0, 2], [1], [3], [4]]
    assert all(len({r.key for r in g}) == 1 for g in groups)


def test_request_queue_admission_and_drain():
    q = RequestQueue(max_queue=2, max_batch=64, max_wait_s=0.0)
    q.offer(_req(0, "a", 1, "x"))
    q.offer(_req(1, "a", 1, "x"))
    with pytest.raises(Overloaded) as ei:
        q.offer(_req(2, "a", 1, "x"))
    assert ei.value.queue_depth == 2 and ei.value.max_queue == 2
    assert ei.value.tenant == "a"
    stop = threading.Event()
    groups = q.take(stop)
    assert [[r.seq for r in g] for g in groups] == [[0, 1]]
    assert q.depth() == 0
    stop.set()
    assert q.take(stop) is None     # stopped + drained -> exit signal


# ---------------------------------------------------------------------------
# the bit-exactness matrix: 6 engines x routing on/off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES, ids=[e.value for e in ENGINES])
@pytest.mark.parametrize("routing", [Routing.NONE, Routing.ROUTED_VERIFIED],
                         ids=["unrouted", "routed"])
def test_coalesced_parity_matrix(engine, routing):
    """Coalesced dispatch == serial per-request search, bit for bit."""
    idx, queries = _build_index(engine)
    stacked, adapter = _stackable(engine, queries)
    svc = IndexService(index=idx, query_adapter=adapter)
    nprobe = 1 if routing is not Routing.NONE else None

    fe = ServingFrontend(max_wait_us=0, start=False)
    fe.register(engine.value, svc)
    # mixed k across one bucket (3, 4 -> 4) plus a second bucket (10 -> 16),
    # overlapping query slices, submitted before the loop starts so the
    # first take() drains and coalesces them all
    slices = [(0, 6, 3), (6, 16, 4), (2, 10, 10), (8, 16, 3)]
    futs = [fe.submit(engine.value, None, k=k, embeddings=stacked[lo:hi],
                      routing=routing, nprobe=nprobe)
            for lo, hi, k in slices]
    fe.start()
    results = [f.result(timeout=120) for f in futs]
    fe.close()

    st = fe.stats()
    assert st["dispatches"] < len(slices)          # coalescing happened
    assert st["coalesce_ratio"] > 1.0
    for (lo, hi, k), (got, gotsims) in zip(slices, results):
        ref, refsims = svc.search(None, k=k, embeddings=stacked[lo:hi],
                                  routing=routing, nprobe=nprobe)
        _assert_result_equal(ref, refsims, got, gotsims,
                             ctx=f"{engine.value} k={k} routing={routing.value}")
        # routed_verified must also equal the unrouted full scan
        if routing is Routing.ROUTED_VERIFIED:
            full, _ = svc.search(None, k=k, embeddings=stacked[lo:hi])
            _assert_result_equal(full, None, got, None,
                                 ctx=f"{engine.value} verified!=full k={k}")


def test_mixed_tenants_concurrent_submitters():
    """All six engines as tenants of ONE front-end, submitted from four
    concurrent client threads: every future resolves to its serial result."""
    tenants = {}
    for engine in ENGINES:
        idx, queries = _build_index(engine, seed=3)
        stacked, adapter = _stackable(engine, queries)
        tenants[engine.value] = (IndexService(index=idx, query_adapter=adapter),
                                 stacked)
    with ServingFrontend(max_wait_us=5000) as fe:
        for name, (svc, _) in tenants.items():
            fe.register(name, svc)

        futs: list[tuple] = []
        flock = threading.Lock()

        def client(worker: int):
            for i, (name, (_, stacked)) in enumerate(tenants.items()):
                lo = (worker + i) % 8
                k = 3 + ((worker + i) % 3)
                f = fe.submit(name, None, k=k, embeddings=stacked[lo:lo + 5])
                with flock:
                    futs.append((name, lo, k, f))

        threads = [threading.Thread(target=client, args=(w,)) for w in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        resolved = [(name, lo, k, f.result(timeout=120))
                    for name, lo, k, f in futs]
        st = fe.stats()
    assert len(resolved) == 4 * len(ENGINES)
    for name, lo, k, (got, gotsims) in resolved:
        svc, stacked = tenants[name]
        ref, refsims = svc.search(None, k=k, embeddings=stacked[lo:lo + 5])
        _assert_result_equal(ref, refsims, got, gotsims, ctx=f"{name} lo={lo}")
    assert set(st["tenants"]) == {e.value for e in ENGINES}


def test_retrieval_service_tenants_with_sims():
    """create_tenant (full RetrievalService stack: embed -> hash -> search ->
    MLE): coalesced results and sims match serial search exactly."""
    rng = np.random.default_rng(0)
    pts = {name: rng.standard_normal((256, 8)).astype(np.float32)
           for name in ("acme", "globex")}
    with ServingFrontend(max_wait_us=200_000, start=False) as fe:
        fe.create_tenant("acme", embed_fn=np.asarray, scheme="e2lsh",
                         m_override=16, max_segments=4)
        fe.create_tenant("globex", embed_fn=np.asarray, scheme="simhash",
                         m_override=32)
        for name, p in pts.items():
            fe.add(name, list(range(128)), embeddings=p[:128])
            fe.add(name, list(range(128, 256)), embeddings=p[128:])
        reqs = [("acme", 0, 5), ("globex", 3, 5), ("acme", 7, 8),
                ("globex", 1, 3), ("acme", 2, 5)]
        futs = [fe.submit(name, None, k=k, embeddings=pts[name][lo:lo + 4] + .01)
                for name, lo, k in reqs]
        fe.start()
        results = [f.result(timeout=120) for f in futs]
        st = fe.stats()
        assert st["dispatches"] < len(reqs)    # per-tenant coalescing
        for (name, lo, k), (got, gotsims) in zip(reqs, results):
            svc = fe._tenants[name].service
            ref, refsims = svc.search(None, k=k,
                                      embeddings=pts[name][lo:lo + 4] + .01)
            _assert_result_equal(ref, refsims, got, gotsims,
                                 ctx=f"{name} k={k}")
            assert gotsims is not None and gotsims.shape == (4, k)


# ---------------------------------------------------------------------------
# admission control, lifecycle, heartbeats
# ---------------------------------------------------------------------------

def _tiny_frontend(**kw) -> tuple[ServingFrontend, np.ndarray]:
    rng = np.random.default_rng(1)
    pts = rng.standard_normal((64, 6)).astype(np.float32)
    fe = ServingFrontend(**kw)
    fe.create_tenant("t", embed_fn=np.asarray, m_override=8)
    fe.add("t", list(range(64)), embeddings=pts)
    return fe, pts


def test_overload_sheds_with_typed_error():
    fe, pts = _tiny_frontend(max_queue=2, max_wait_us=0, start=False)
    fe.submit("t", None, k=2, embeddings=pts[:1])
    fe.submit("t", None, k=2, embeddings=pts[:1])
    with pytest.raises(Overloaded) as ei:
        fe.submit("t", None, k=2, embeddings=pts[:1])
    assert ei.value.tenant == "t"
    assert fe.stats()["tenants"]["t"]["shed"] == 1
    assert fe.stats()["pending_requests"] == 2   # shed request not counted
    fe.start()
    fe.close()
    assert fe.stats()["pending_requests"] == 0   # close() drained the queue
    with pytest.raises(RuntimeError, match="closed"):
        fe.submit("t", None, k=2, embeddings=pts[:1])


def test_drain_waits_then_removes_tenant():
    fe, pts = _tiny_frontend(max_wait_us=0)
    futs = [fe.submit("t", None, k=3, embeddings=pts[:2]) for _ in range(3)]
    fe.drain("t", timeout=60)
    for f in futs:                       # admitted work completed, not dropped
        res, _ = f.result(timeout=0)
        assert res.ids.shape == (2, 3)
    assert fe.tenants() == []
    with pytest.raises(KeyError, match="unknown tenant"):
        fe.submit("t", None, k=3, embeddings=pts[:2])
    # the slot is recycled for a new tenant
    fe.create_tenant("t2", embed_fn=np.asarray, m_override=8)
    fe.add("t2", [0, 1], embeddings=pts[:2])
    res, _ = fe.search("t2", None, k=1, embeddings=pts[:1])
    assert res.ids.shape == (1, 1)
    fe.close()


def test_heartbeat_idle_tenants_and_reap():
    fe, pts = _tiny_frontend(heartbeat_timeout_s=30.0)
    fe.search("t", None, k=2, embeddings=pts[:1])
    now = time.time()
    assert fe.idle_tenants(now=now) == []
    assert fe.idle_tenants(now=now + 300) == ["t"]      # heartbeat expired
    assert fe.reap_idle(now=now + 300, timeout=60) == ["t"]
    assert fe.tenants() == []
    fe.close()


def test_draining_tenant_rejects_submit_and_add():
    fe, pts = _tiny_frontend(max_wait_us=0)
    fe._tenants["t"].draining = True
    with pytest.raises(ValueError, match="draining"):
        fe.submit("t", None, k=2, embeddings=pts[:1])
    with pytest.raises(ValueError, match="draining"):
        fe.add("t", [99], embeddings=pts[:1])
    fe.close()


# ---------------------------------------------------------------------------
# empty-batch validation (satellite): the contract, not a shape error
# ---------------------------------------------------------------------------

def test_empty_query_batch_raises_contract_error():
    rng = np.random.default_rng(0)
    pts = rng.standard_normal((32, 4)).astype(np.float32)
    svc = RetrievalService(embed_fn=np.asarray, m_override=8)
    svc.add(list(range(32)), embeddings=pts)
    for bad in (dict(queries=[]), dict(queries=iter(())),
                dict(queries=None, embeddings=np.empty((0, 4), np.float32))):
        with pytest.raises(ValueError, match="empty batch of queries"):
            svc.search(bad.get("queries"), k=3,
                       embeddings=bad.get("embeddings"))
    # the front-end rejects synchronously on the submitter's thread
    fe = ServingFrontend(start=False)
    fe.register("t", svc)
    with pytest.raises(ValueError, match="empty batch of queries"):
        fe.submit("t", [], k=3)
    # and the raw-index backend mirrors the same contract
    idx, _ = _build_index(Engine.EQ)
    with pytest.raises(ValueError, match="empty batch of queries"):
        IndexService(index=idx).search(np.empty((0, 16), np.int32), k=3)
    # the add() side of the mirror (pre-existing contract, kept)
    with pytest.raises(ValueError, match="empty batch of items"):
        svc.add([], embeddings=np.empty((0, 4), np.float32))


# ---------------------------------------------------------------------------
# cache invalidation under churn (satellite): router + placement refresh
# exactly when the corpus fingerprint changes
# ---------------------------------------------------------------------------

def test_router_cache_refreshes_exactly_on_corpus_change():
    rng = np.random.default_rng(2)
    pts = rng.standard_normal((96, 6)).astype(np.float32)
    svc = RetrievalService(embed_fn=np.asarray, m_override=8, max_segments=2)
    svc.add(list(range(32)), embeddings=pts[:32])

    builds = []
    orig = svc._index.router
    svc._index.router = lambda: builds.append(1) or orig()
    q = pts[:4] + 0.01

    def routed_search():
        return svc.search(None, k=3, embeddings=q, routing="routed_verified",
                          nprobe=1)

    routed_search()
    assert len(builds) == 1                  # built on first routed search
    routed_search()
    routed_search()
    assert len(builds) == 1                  # cached: fingerprint unchanged
    svc.add(list(range(32, 64)), embeddings=pts[32:64])
    routed_search()
    assert len(builds) == 2                  # add() changed the fingerprint
    routed_search()
    assert len(builds) == 2
    # 3rd add exceeds max_segments=2 -> compaction also changes the
    # fingerprint (segment count + compaction counter)
    svc.add(list(range(64, 96)), embeddings=pts[64:])
    assert svc._index.compaction_count == 1
    routed_search()
    assert len(builds) == 3
    # results always reflect the current corpus, never the cached router's
    res, _ = routed_search()
    full, _ = svc.search(None, k=3, embeddings=q)
    assert np.array_equal(np.asarray(res.ids), np.asarray(full.ids))


def test_plan_trace_counter_flat_across_warm_searches():
    """The per-plan trace-counter spy: repeated searches on a fixed corpus
    reuse compiled part kernels (no new traces), and corpus growth with
    equal-shaped segments stays on the cached kernels too."""
    rng = np.random.default_rng(4)
    pts = rng.standard_normal((96, 6)).astype(np.float32)
    svc = RetrievalService(embed_fn=np.asarray, m_override=8, max_segments=8)
    svc.add(list(range(48)), embeddings=pts[:48])
    q = pts[:4] + 0.01
    svc.search(None, k=3, embeddings=q)                    # warm
    before = sum(plan_lib._TRACE_COUNTS.values())
    for _ in range(3):
        svc.search(None, k=3, embeddings=q)
    assert sum(plan_lib._TRACE_COUNTS.values()) == before  # all cache hits
    svc.add(list(range(48, 96)), embeddings=pts[48:])      # same 48-row shape
    svc.search(None, k=3, embeddings=q)
    assert sum(plan_lib._TRACE_COUNTS.values()) == before  # shared part kernel


def test_sharded_placement_cache_refreshes_on_churn():
    """Mesh-backed tenant: the sharded placement is reused across searches
    and rebuilt exactly when the corpus fingerprint changes."""
    import jax

    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_mesh((1,), ("data",))
    rng = np.random.default_rng(5)
    pts = rng.standard_normal((64, 6)).astype(np.float32)
    fe = ServingFrontend(mesh=mesh, max_wait_us=0)
    svc = fe.create_tenant("t", embed_fn=np.asarray, m_override=8)
    fe.add("t", list(range(32)), embeddings=pts[:32])
    q = pts[:3] + 0.01

    res1, _ = fe.search("t", None, k=3, embeddings=q)
    placed1 = svc._placed
    res2, _ = fe.search("t", None, k=3, embeddings=q)
    assert svc._placed is placed1            # cache hit: same placement tuple
    assert np.array_equal(np.asarray(res1.ids), np.asarray(res2.ids))
    fe.add("t", list(range(32, 64)), embeddings=pts[32:])
    res3, _ = fe.search("t", None, k=3, embeddings=q)
    assert svc._placed is not placed1        # fingerprint change -> re-place
    # and the new placement serves the grown corpus: parity with a fresh
    # single-device service over the same corpus
    ref = RetrievalService(embed_fn=np.asarray, m_override=8)
    ref.add(list(range(32)), embeddings=pts[:32])
    ref.add(list(range(32, 64)), embeddings=pts[32:])
    expect, _ = ref.search(None, k=3, embeddings=q)
    assert np.array_equal(np.asarray(expect.ids), np.asarray(res3.ids))
    assert np.array_equal(np.asarray(expect.counts), np.asarray(res3.counts))
    fe.close()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    assert percentile([], 50) == 0.0
    assert percentile([5.0], 99) == 5.0
    xs = list(range(1, 101))
    assert percentile(xs, 50) == 51          # nearest rank on 100 samples
    assert percentile(xs, 99) == 99
    with pytest.raises(ValueError):
        percentile(xs, 101)


def test_metrics_snapshot_schema_and_ratios():
    m = FrontendMetrics(window=16)
    for _ in range(4):
        m.record_submit("a", 8)
    m.record_shed("a")
    m.record_dispatch(n_requests=4, n_queries=32)
    for lat in (0.010, 0.020, 0.030, 0.040):
        m.record_completion("a", lat)
    m.record_queue_depth(3)
    m.record_queue_depth(1)
    snap = m.snapshot()
    assert snap["coalesce_ratio"] == 4.0
    assert snap["batch_occupancy"] == 32.0
    assert snap["queue_depth"] == 1 and snap["queue_high_water"] == 3
    t = snap["tenants"]["a"]
    assert t["submitted"] == 4 and t["shed"] == 1 and t["completed"] == 4
    assert t["p50_ms"] == pytest.approx(30.0)   # nearest rank of 4 samples
    assert 0 < t["p50_ms"] <= t["p99_ms"]
    m.forget_tenant("a")
    assert "a" not in m.snapshot()["tenants"]
