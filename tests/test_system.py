"""End-to-end behaviour tests for the GENIE system (paper sections III-VI)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GenieIndex, TopKMethod
from repro.core.lsh import e2lsh, rbh, tau_ann
from repro.core.postings import PostingsIndex
from repro.data.pipeline import synthetic_points


def _build_ann_index(rng, n=800, d=16, m=64):
    pts, labels = synthetic_points(n, d, n_clusters=10, seed=3)
    params = e2lsh.make(jax.random.PRNGKey(0), d=d, m=m, w=4.0, n_buckets=67)
    sigs = e2lsh.hash_points(params, jnp.asarray(pts))
    return pts, labels, params, GenieIndex.build_lsh(sigs, max_count=m)


def test_ann_search_finds_perturbed_points(rng):
    pts, _, params, idx = _build_ann_index(rng)
    q = pts[:16] + rng.standard_normal((16, 16)).astype(np.float32) * 0.05
    qsigs = e2lsh.hash_points(params, jnp.asarray(q))
    res = idx.search(qsigs, k=5)
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(16))


def test_ann_approximation_ratio_close_to_one(rng):
    """Paper Fig 14: approximation ratio stays near 1."""
    pts, _, params, idx = _build_ann_index(rng, n=1000, m=128)
    q = pts[:8] + rng.standard_normal((8, 16)).astype(np.float32) * 0.2
    qsigs = e2lsh.hash_points(params, jnp.asarray(q))
    res = idx.search(qsigs, k=10)
    dists = np.linalg.norm(pts[None] - q[:, None], axis=-1)  # [Q, N]
    true_knn = np.sort(dists, axis=1)[:, :10]
    got = np.take_along_axis(dists, np.asarray(res.ids), axis=1)
    ratio = float(np.mean(np.sort(got, axis=1) / np.maximum(true_knn, 1e-9)))
    assert ratio < 1.6, ratio


def test_knn_label_prediction_rbh(rng):
    """Paper Table V analogue: 1NN prediction via RBH Laplacian-kernel ANN."""
    pts, labels, _, _ = _build_ann_index(rng)
    sigma = rbh.median_heuristic_sigma(jnp.asarray(pts), jax.random.PRNGKey(1))
    params = rbh.make(jax.random.PRNGKey(2), d=16, m=128, sigma=sigma, n_buckets=8192)
    train, test = pts[100:], pts[:100]
    ltrain, ltest = labels[100:], labels[:100]
    idx = GenieIndex.build_lsh(rbh.hash_points(params, jnp.asarray(train)), max_count=128)
    res = idx.search(rbh.hash_points(params, jnp.asarray(test)), k=1)
    pred = ltrain[np.asarray(res.ids)[:, 0]]
    acc = float(np.mean(pred == ltest))
    assert acc > 0.9, acc


def test_multiload_matches_single_load(rng):
    pts, _, params, idx = _build_ann_index(rng)
    q = pts[:8] + 0.05
    qsigs = e2lsh.hash_points(params, jnp.asarray(q))
    full = idx.search(qsigs, k=6)
    parts = idx.search_multiload(qsigs, k=6, n_parts=5)
    assert np.array_equal(np.asarray(full.counts), np.asarray(parts.counts))


def test_all_topk_methods_agree(rng):
    _, _, params, idx = _build_ann_index(rng)
    q = rng.standard_normal((4, 16)).astype(np.float32)
    qsigs = e2lsh.hash_points(params, jnp.asarray(q))
    r1 = idx.search(qsigs, k=9, method=TopKMethod.CPQ)
    r2 = idx.search(qsigs, k=9, method=TopKMethod.SORT)
    r3 = idx.search(qsigs, k=9, method=TopKMethod.SPQ)
    assert np.array_equal(np.asarray(r1.counts), np.asarray(r2.counts))
    assert np.array_equal(np.asarray(r1.counts), np.asarray(r3.counts))


def test_postings_engine_matches_dense(rng):
    """The GPU-faithful CSR postings engine == the TPU dense engine."""
    n, m, buckets = 300, 12, 32
    sigs = rng.integers(0, buckets, size=(n, m)).astype(np.int32)
    keywords = sigs + (np.arange(m, dtype=np.int32) * buckets)[None, :]
    pidx = PostingsIndex.build(keywords, n_keywords=m * buckets)
    q = keywords[:5]
    counts_np = pidx.scan_counts_numpy(q)
    from repro.core import match

    counts_dense = np.asarray(match.match_eq(jnp.asarray(sigs), jnp.asarray(sigs[:5])))
    assert np.array_equal(counts_np, counts_dense)
    # tiled (load-balanced) device scan agrees too
    tiles, tile_kw = pidx.split_tiles(limit=64)
    counts_tiled = np.asarray(
        pidx.scan_counts_tiled(jnp.asarray(tiles), jnp.asarray(tile_kw), jnp.asarray(q))
    )
    assert np.array_equal(counts_tiled, counts_np)


def test_retrieval_service_end_to_end(rng):
    from repro.serve.retrieval import RetrievalService

    pts, labels, _, _ = _build_ann_index(rng)
    svc = RetrievalService(embed_fn=lambda x: np.asarray(x), m_override=96)
    svc.add(list(range(len(pts))), embeddings=pts)
    res, sims = svc.search(None, k=3, embeddings=pts[:5] + 0.02)
    assert np.array_equal(np.asarray(res.ids)[:, 0], np.arange(5))
    assert sims.shape == (5, 3)
    assert np.all(sims <= 1.0) and np.all(sims >= 0.0)
