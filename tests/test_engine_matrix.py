"""Engine conformance matrix: every registered engine x {reference, kernel}
x {search, multiload, distributed} must return identical top-k ids/counts.

This is the standing acceptance harness for the registry's genericity claim:
a new engine registered with an `example` generator (MatchModel.example) gets
the full parity matrix, the pad-value conformance check, and the tie-break
consistency sweep for free -- no new test code.  `test_matrix_covers_every_
engine` fails loudly if an engine is registered without conformance data.

All paths share select_topk's deterministic (count desc, id asc) ordering, so
ids are compared exactly, not just counts.
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GenieIndex, cpq, engines, select
from repro.core.types import Engine, SearchParams, TopKMethod

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MATRIX_ENGINES = sorted(engines.available(), key=lambda e: e.value)


def _example(engine: Engine, seed: int = 0, n: int = 96, q: int = 4):
    """(model, prepared data, raw queries, resolved max_count) from the
    engine's own conformance generator."""
    model = engines.get(engine)
    assert model.example is not None, f"{engine.value}: no MatchModel.example"
    raw, queries, mc = model.example(np.random.default_rng(seed), n, q)
    data = model.prepare_data(raw)
    return model, data, queries, model.resolve_max_count(data, mc)


def _assert_same_topk(got, want, label=""):
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts)), label
    assert np.array_equal(np.asarray(got.ids), np.asarray(want.ids)), label


def test_matrix_covers_every_engine():
    """Every registered engine must ship conformance data -- future engines
    cannot silently opt out of the matrix."""
    missing = [e.value for e in engines.available() if engines.get(e).example is None]
    assert not missing, f"engines without MatchModel.example: {missing}"
    assert {Engine.TANIMOTO, Engine.COSINE} <= set(engines.available())


@pytest.mark.parametrize("engine", MATRIX_ENGINES)
def test_matrix_search_kernel_reference_parity(engine):
    """Single-device search: kernel and reference paths agree with the sort
    oracle on ids and counts."""
    model, data, queries, mc = _example(engine)
    oracle = cpq.sort_select(
        model.match_counts(data, queries, use_kernel=False),
        SearchParams(k=9, max_count=mc),
    )
    for use_kernel in (False, True):
        idx = GenieIndex.build(engine, data, max_count=mc, use_kernel=use_kernel)
        got = idx.search(queries, k=9)
        _assert_same_topk(got, oracle, f"{engine.value} kernel={use_kernel}")


@pytest.mark.parametrize("engine", MATRIX_ENGINES)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_matrix_multiload_parity(engine, use_kernel):
    """Streamed multiload (uneven split, both match paths) == full search."""
    model, data, queries, mc = _example(engine, n=97)   # uneven on purpose
    idx = GenieIndex.build(engine, data, max_count=mc, use_kernel=use_kernel)
    full = idx.search(queries, k=6)
    for n_parts in (1, 3, 5):
        part = idx.search_multiload(queries, k=6, n_parts=n_parts)
        _assert_same_topk(part, full,
                          f"{engine.value} kernel={use_kernel} parts={n_parts}")


def test_matrix_distributed_parity():
    """Every engine x {reference, kernel} through the sharded search step (8
    forced CPU devices via subprocess: jax locks the device count at first
    init).  use_kernel=True runs the Pallas kernels *inside* shard_map."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed, engines, cpq
        from repro.core.types import SearchParams
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((2, 4), ('data', 'model'))
        for eng in sorted(engines.available(), key=lambda e: e.value):
            model = engines.get(eng)
            raw, rawq, mc = model.example(np.random.default_rng(0), 128, 4)
            data = model.prepare_data(raw)
            queries = model.prepare_queries(rawq)
            mx = model.resolve_max_count(data, mc)
            dd = jax.device_put(data, distributed.data_sharding(mesh))
            qq = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, distributed.replicated(mesh, 2)), queries)
            want = cpq.sort_select(model.reference(data, queries),
                                   SearchParams(k=7, max_count=mx))
            for use_kernel in (False, True):
                params = SearchParams(k=7, max_count=mx, use_kernel=use_kernel)
                res = distributed.make_search_step(mesh, params, eng)(dd, qq)
                assert np.array_equal(np.asarray(res.counts), np.asarray(want.counts)), \\
                    (eng, use_kernel)
                assert np.array_equal(np.asarray(res.ids), np.asarray(want.ids)), \\
                    (eng, use_kernel)
        print('distributed matrix parity OK')
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "distributed matrix parity OK" in out.stdout


# ---------------------------------------------------------------------------
# Pad-value conformance (the multiload fill contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", MATRIX_ENGINES)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_matrix_pad_rows_never_reach_topk(engine, use_kernel):
    """Padded multiload rows can never enter the top-k, even when the last
    part is almost entirely padding and k exceeds its real rows.  Pad columns
    are masked to count -1 before per-part selection, so the guarantee holds
    for every engine regardless of how its pad_value scores (COSINE's zero
    fill, for instance, scores V/2 against any query)."""
    n = 50
    model, data, queries, mc = _example(engine, n=n)
    idx = GenieIndex.build(engine, data, max_count=mc, use_kernel=use_kernel)
    # 8 parts of 7 -> last part has 1 real row + 6 pad rows; k=10 > real rows
    res = idx.search_multiload(queries, k=10, n_parts=8)
    ids = np.asarray(res.ids)
    counts = np.asarray(res.counts)
    assert ids.max() < n, f"{engine.value}: pad id {ids.max()} in top-k"
    assert np.all(counts[ids < 0] == -1)            # empty slots stay sentinel
    full = idx.search(queries, k=10)
    _assert_same_topk(res, full, engine.value)


@pytest.mark.parametrize("engine", MATRIX_ENGINES)
def test_matrix_pad_value_representable(engine):
    """The declared pad_value must survive the round-trip into the prepared
    data dtype (the fill GenieIndex.search_multiload performs)."""
    model, data, _, _ = _example(engine, n=8)
    fill = jnp.full((2,) + data.shape[1:], model.pad_value, dtype=data.dtype)
    assert fill.dtype == data.dtype
    assert bool(jnp.all(fill == jnp.asarray(model.pad_value).astype(data.dtype)))


# ---------------------------------------------------------------------------
# PACKED signature layout (core/packing.py): bit-for-bit parity with WIDE
# ---------------------------------------------------------------------------

PACKED_ENGINES = [e for e in MATRIX_ENGINES if engines.get(e).supports_packed]
WIDE_ONLY_ENGINES = [e for e in MATRIX_ENGINES if not engines.get(e).supports_packed]


def test_matrix_packed_covers_expected_engines():
    assert set(PACKED_ENGINES) == {Engine.TANIMOTO, Engine.COSINE}


@pytest.mark.parametrize("engine", PACKED_ENGINES)
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("method", [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT])
def test_matrix_packed_wide_parity(engine, use_kernel, method):
    """PACKED search returns bit-for-bit the WIDE ids and counts for every
    selection method and both match paths (use_kernel=True with PACKED takes
    the fused match->count->local-top-k kernel)."""
    model, data, queries, mc = _example(engine, n=97)   # V=32 words + ragged n
    wide = GenieIndex.build(engine, data, max_count=mc, use_kernel=use_kernel)
    packed = GenieIndex.build(engine, data, max_count=mc, use_kernel=use_kernel,
                              signature_layout="packed")
    want = wide.search(queries, k=9, method=method)
    got = packed.search(queries, k=9, method=method)
    _assert_same_topk(got, want,
                      f"{engine.value} kernel={use_kernel} {method.value}")


@pytest.mark.parametrize("engine", PACKED_ENGINES)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_matrix_packed_pad_rows_never_reach_topk(engine, use_kernel):
    """The packed multiload fill (0 words / 255 bytes) can never enter the
    top-k -- same contract as the WIDE pad sweep above."""
    n = 50
    model, data, queries, mc = _example(engine, n=n)
    idx = GenieIndex.build(engine, data, max_count=mc, use_kernel=use_kernel,
                           signature_layout="packed")
    res = idx.search_multiload(queries, k=10, n_parts=8)
    ids = np.asarray(res.ids)
    counts = np.asarray(res.counts)
    assert ids.max() < n, f"{engine.value}: pad id {ids.max()} in top-k"
    assert np.all(counts[ids < 0] == -1)
    full = idx.search(queries, k=10)
    _assert_same_topk(res, full, engine.value)


@pytest.mark.parametrize("engine", WIDE_ONLY_ENGINES)
def test_matrix_packed_rejects_unsupported_engines(engine):
    """Engines without a packed format fail loudly at build, not at search."""
    model, data, _, mc = _example(engine, n=8)
    with pytest.raises(ValueError, match="no packed signature format"):
        GenieIndex.build(engine, data, max_count=mc, signature_layout="packed")


# ---------------------------------------------------------------------------
# Tie-break consistency across selection methods
# ---------------------------------------------------------------------------

def _degenerate_counts():
    rng = np.random.default_rng(7)
    q, n = 3, 64
    return {
        "all-equal": np.full((q, n), 5, dtype=np.int32),
        "two-valued": rng.choice([2, 9], size=(q, n)).astype(np.int32),
        "k-boundary-tie": np.concatenate(       # k=5 cuts through the 5-ties
            [np.full((q, 3), 9, np.int32), np.full((q, n - 3), 5, np.int32)], axis=1),
        "all-zero": np.zeros((q, n), dtype=np.int32),
    }


@pytest.mark.parametrize("name", sorted(_degenerate_counts()))
@pytest.mark.parametrize("method", [TopKMethod.CPQ, TopKMethod.SPQ, TopKMethod.SORT])
def test_matrix_tie_break_consistency(name, method):
    """CPQ, SPQ, and sort agree *exactly* (ids included) on count-degenerate
    inputs: every path orders by (count desc, id asc) -- CPQ/SPQ fill their
    candidate buffers in id order and break count ties with a stable sort,
    lax.top_k returns the lowest index among ties.  Divergence here would
    make multiload/distributed results depend on the selection method."""
    counts = jnp.asarray(_degenerate_counts()[name])
    params = SearchParams(k=5, max_count=10, method=method)
    got = select.select_topk(counts, params)
    want = cpq.sort_select(counts, SearchParams(k=5, max_count=10))
    assert np.array_equal(np.asarray(got.counts), np.asarray(want.counts)), name
    assert np.array_equal(np.asarray(got.ids), np.asarray(want.ids)), name
    # the k-th count (Theorem 3.1's AT-1) must agree across methods too
    assert np.array_equal(np.asarray(got.counts[:, -1]),
                          np.asarray(want.counts[:, -1])), name
