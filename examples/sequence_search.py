"""SA sequence search under edit distance (paper section V-A): n-gram
decomposition, match-count filtering, batched DP verification, and the
Theorem 5.2 exactness certificate.

    PYTHONPATH=src python examples/sequence_search.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import GenieIndex
from repro.core.sa import ngram, verify
from repro.data.pipeline import mutate_sequence, synthetic_sequences


def main():
    n, v, K = 3, 4096, 32
    seqs = synthetic_sequences(5_000, length=40, seed=0)
    index = GenieIndex.build_minsum(ngram.count_vectors(seqs, n, v), max_count=127,
                                    use_kernel=False)

    for rate in (0.1, 0.3):
        target = 1234
        query = mutate_sequence(seqs[target], rate, seed=7)
        qv = jnp.asarray(ngram.count_vector(query, n, v)[None])
        res = index.search(qv, k=K)
        ids = np.asarray(res.ids[0])

        cand = [seqs[i] if i >= 0 else "" for i in ids]
        enc, lens = ngram.encode_sequences(cand, 48)
        qenc, qlen = ngram.encode_sequences([query], 48)
        out = verify.verify_topk(jnp.asarray(qenc[0]), jnp.int32(qlen[0]),
                                 jnp.asarray(enc), jnp.asarray(lens),
                                 jnp.asarray(np.asarray(res.counts[0])), k=1, n=n)
        best = int(ids[int(np.asarray(out["order"])[0])])
        print(f"modification {rate:.0%}: best candidate id={best} "
              f"(target {target}, ed={int(np.asarray(out['edit_distances'])[0])}, "
              f"certified_exact={bool(np.asarray(out['certified_exact']))})")


if __name__ == "__main__":
    main()
