"""GENIE quickstart: build an inverted index through the MatchModel registry,
run a batched tau-ANN search, and inspect the c-PQ guarantees.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, GenieIndex, SegmentedIndex, TopKMethod, engines
from repro.core import lsh as lsh_lib
from repro.core.lsh import tau_ann
from repro.data.pipeline import synthetic_points


def main():
    # 0. the registry is the system's single dispatch point: every engine is
    #    one descriptor, every search path resolves through it
    print("registered engines:",
          ", ".join(e.value for e in engines.available()))
    print("registered LSH schemes:", ", ".join(lsh_lib.scheme_names()))

    # 1. data: 20K clustered points (SIFT-like stand-in)
    pts, _ = synthetic_points(20_000, dim=32, n_clusters=64, seed=0)

    # 2. LSH transform via the scheme registry: the paper's practical m
    #    (Fig 8) at eps = delta = 0.06
    m = tau_ann.required_m(0.06, 0.06)
    print(f"hash functions m = {m} (paper: 237; Theorem 4.1 bound: "
          f"{tau_ann.m_theorem41(0.06, 0.06)})")
    scheme = lsh_lib.get_scheme("e2lsh")
    params = scheme.make_params(jax.random.PRNGKey(0), d=32, m=m, w=4.0, n_buckets=67)
    sigs = scheme.hash_points(params, jnp.asarray(pts))

    # 3. build the index: the generic registry builder (named aliases like
    #    build_lsh remain as thin wrappers)
    index = GenieIndex.build(Engine.EQ, sigs, use_kernel=False)
    print(f"index: {index.stats.n_objects} objects, "
          f"{index.stats.bytes_device/1e6:.1f} MB on device "
          f"(engine={index.stats.extra['engine']})")

    # 4. batched search: 128 noisy queries
    rng = np.random.default_rng(1)
    q = pts[:128] + rng.standard_normal((128, 32)).astype(np.float32) * 0.1
    qsigs = scheme.hash_points(params, jnp.asarray(q))
    res = index.search(qsigs, k=10, method=TopKMethod.CPQ)

    hit = float(np.mean(np.asarray(res.ids)[:, 0] == np.arange(128)))
    print(f"top-1 self-retrieval: {hit:.3f}")
    print(f"MC_k threshold (Theorem 3.1, AT-1) for query 0: {int(res.threshold[0])}")
    sims = tau_ann.mle_similarity(np.asarray(res.counts[:1]), m)
    print(f"similarity estimates (Eqn 7) for query 0: {np.round(sims, 3)}")

    # 5. the same index streamed as 4 parts (paper section III-D) -- identical
    #    counts, any registered engine
    parts = index.search_multiload(qsigs, k=10, n_parts=4)
    same = bool(np.array_equal(np.asarray(res.counts), np.asarray(parts.counts)))
    print(f"multiload(4 parts) counts identical: {same}")

    # 6. the same machinery, different measures: sign-quantized cosine
    #    (simhash bits -> COSINE sign agreements on the MXU) and Jaccard
    #    sketches (minhash -> TANIMOTO collision counts, FLASH-style)
    sub = jnp.asarray(pts[:4000])
    sh = lsh_lib.get_scheme("simhash")
    sh_params = sh.make_params(jax.random.PRNGKey(1), d=32, m=128)
    cos_idx = GenieIndex.build(sh.engine, sh.hash_points(sh_params, sub),
                               use_kernel=False)
    cres = cos_idx.search(sh.hash_points(sh_params, jnp.asarray(q[:16])), k=5)
    cos_hat = sh.mle(np.asarray(cres.counts[:1]), cos_idx.max_count)
    print(f"COSINE engine: top-1 self-retrieval "
          f"{float(np.mean(np.asarray(cres.ids)[:, 0] == np.arange(16))):.3f}, "
          f"cos estimates q0: {np.round(cos_hat[0], 3)}")

    # 6.5 incremental growth: seal each arriving batch into an immutable
    #     segment (O(batch) per add, no rebuild), search across segments with
    #     the exact cap-buffer merge, then compact -- results never change
    seg = SegmentedIndex(engine=Engine.EQ, max_count=m, use_kernel=False)
    for start in range(0, sigs.shape[0], 6000):       # uneven final batch
        seg.add(sigs[start:start + 6000])
    sres = seg.search(qsigs, k=10)
    same = bool(np.array_equal(np.asarray(res.ids), np.asarray(sres.ids)))
    print(f"segmented add ({seg.stats.n_segments} segments, rows "
          f"{seg.stats.segment_rows}): top-k identical to monolithic: {same}")
    seg.compact(max_segments=1)
    sres = seg.search(qsigs, k=10)
    print(f"after compact(1): {seg.stats.n_segments} segment, "
          f"{seg.stats.compaction_count} compaction, top-k identical: "
          f"{bool(np.array_equal(np.asarray(res.ids), np.asarray(sres.ids)))}")

    mh = lsh_lib.get_scheme("minhash")
    mh_params = mh.make_params(jax.random.PRNGKey(2), d=32, m=96, n_buckets=8192)
    tan_idx = GenieIndex.build(mh.engine, mh.hash_points(mh_params, sub),
                               use_kernel=False)
    tres = tan_idx.search(mh.hash_points(mh_params, jnp.asarray(q[:16])), k=5)
    print(f"TANIMOTO engine: top-1 self-retrieval "
          f"{float(np.mean(np.asarray(tres.ids)[:, 0] == np.arange(16))):.3f}, "
          f"Jaccard MLE q0: {np.round(mh.mle(np.asarray(tres.counts[:1]), 96)[0], 3)}")


if __name__ == "__main__":
    main()
