"""GENIE quickstart: build an LSH inverted index, run a batched tau-ANN
search, and inspect the c-PQ guarantees.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GenieIndex, TopKMethod
from repro.core.lsh import e2lsh, tau_ann
from repro.data.pipeline import synthetic_points


def main():
    # 1. data: 20K clustered points (SIFT-like stand-in)
    pts, _ = synthetic_points(20_000, dim=32, n_clusters=64, seed=0)

    # 2. LSH transform: the paper's practical m (Fig 8) at eps = delta = 0.06
    m = tau_ann.required_m(0.06, 0.06)
    print(f"hash functions m = {m} (paper: 237; Theorem 4.1 bound: "
          f"{tau_ann.m_theorem41(0.06, 0.06)})")
    params = e2lsh.make(jax.random.PRNGKey(0), d=32, m=m, w=4.0, n_buckets=67)
    sigs = e2lsh.hash_points(params, jnp.asarray(pts))

    # 3. build the index (device-resident signature matrix)
    index = GenieIndex.build_lsh(sigs, use_kernel=False)
    print(f"index: {index.stats.n_objects} objects, "
          f"{index.stats.bytes_device/1e6:.1f} MB on device")

    # 4. batched search: 128 noisy queries
    rng = np.random.default_rng(1)
    q = pts[:128] + rng.standard_normal((128, 32)).astype(np.float32) * 0.1
    qsigs = e2lsh.hash_points(params, jnp.asarray(q))
    res = index.search(qsigs, k=10, method=TopKMethod.CPQ)

    hit = float(np.mean(np.asarray(res.ids)[:, 0] == np.arange(128)))
    print(f"top-1 self-retrieval: {hit:.3f}")
    print(f"MC_k threshold (Theorem 3.1, AT-1) for query 0: {int(res.threshold[0])}")
    sims = tau_ann.mle_similarity(np.asarray(res.counts[:1]), m)
    print(f"similarity estimates (Eqn 7) for query 0: {np.round(sims, 3)}")


if __name__ == "__main__":
    main()
