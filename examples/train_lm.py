"""Training driver: train a model from the zoo on the synthetic pipeline with
checkpoint/restart.  Defaults to a quick CPU demo config; pass --arch
smollm-360m --steps 300 for the ~100M-class run on real hardware.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m-smoke --steps 60
"""
import argparse

from repro.data.pipeline import DataConfig
from repro.models.registry import get_api, get_config, list_archs
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainHParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke", choices=list_archs())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = get_api(cfg)
    hp = TrainHParams(
        optimizer=AdamWConfig(lr=args.lr), total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        grad_compression=args.grad_compression,
    )
    tc = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 4, 1), log_every=10)
    trainer = Trainer(cfg, api, hp, tc, DataConfig(global_batch=args.batch, seq_len=args.seq))
    history = trainer.run()
    for rec in history:
        print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"grad_norm {rec['grad_norm']:.3f}  {rec['seconds']*1e3:.0f} ms")
    print(f"final loss: {history[-1]['loss']:.4f} (from {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
