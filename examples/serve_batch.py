"""End-to-end serving driver (the paper's kind: high-throughput batched
similarity queries).  A GENIE RetrievalService indexes document embeddings
produced by a small LM from the model zoo; batches of 1024 queries are
answered with tau-ANN search + c-PQ selection, and the LM decodes a
continuation for the top hit -- retrieval-augmented serving with the paper's
technique as the retrieval layer.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticTokens, synthetic_documents
from repro.core.sa import document
from repro.models.registry import get_api, get_config
from repro.serve import RetrievalService, ServeEngine


def main():
    # --- a small LM from the zoo provides the embedding + decode stack ---
    cfg = get_config("smollm-360m-smoke")
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    def embed(texts):
        """Mean-pooled binary word vectors projected through the embedding
        table (toy embedder; production would mean-pool hidden states)."""
        vecs = document.binary_vectors(list(texts), 512).astype(np.float32)
        table = np.asarray(params["embed"], np.float32)  # [512, d]
        return vecs @ table

    # --- index 20K documents with GENIE ---
    docs = synthetic_documents(20_000, seed=3)
    svc = RetrievalService(embed_fn=embed, m_override=128, n_buckets=1024)
    t0 = time.time()
    svc.add(docs)
    print(f"indexed {len(docs)} docs in {time.time()-t0:.2f}s (m={svc.m})")

    # --- batched retrieval: 1024 queries per batch (paper's regime) ---
    queries = [docs[i] for i in range(0, 4096, 4)]
    t0 = time.time()
    res, sims = svc.search(queries, k=5)
    dt = time.time() - t0
    hit1 = float(np.mean(np.asarray(res.ids)[:, 0] == np.arange(0, 4096, 4)))
    print(f"searched {len(queries)} queries in {dt:.2f}s "
          f"({len(queries)/dt:.0f} qps); top-1 self-retrieval {hit1:.3f}")

    # --- decode a continuation conditioned on the top hit ---
    eng = ServeEngine(cfg, api, params, cache_cap=64)
    batch = SyntheticTokens(cfg, DataConfig(global_batch=4, seq_len=16)).batch(0)
    toks, stats = eng.generate(batch, max_new_tokens=16)
    print(f"decoded {stats.tokens_generated} tokens at "
          f"{stats.decode_tokens_per_s:.0f} tok/s (CPU)")


if __name__ == "__main__":
    main()
