"""ANN search in Laplacian kernel space via Random Binning Hashing (paper
section IV-A3, the OCR experiment): kernel-width heuristic, RBH signatures,
re-hashing to a finite bucket space, and 1NN label prediction.

    PYTHONPATH=src python examples/ann_kernel_space.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GenieIndex
from repro.core.lsh import rbh
from repro.data.pipeline import synthetic_points


def main():
    d, m = 32, 128
    pts, labels = synthetic_points(10_000, d, n_clusters=26, seed=4)

    sigma = rbh.median_heuristic_sigma(jnp.asarray(pts), jax.random.PRNGKey(0))
    print(f"kernel width sigma = {sigma:.2f} (mean pairwise l1, Jaakkola heuristic)")
    params = rbh.make(jax.random.PRNGKey(1), d=d, m=m, sigma=sigma, n_buckets=8192)

    train, test = pts[1000:], pts[:1000]
    ltrain, ltest = labels[1000:], labels[:1000]
    index = GenieIndex.build_lsh(rbh.hash_points(params, jnp.asarray(train)),
                                 max_count=m, use_kernel=False)
    res = index.search(rbh.hash_points(params, jnp.asarray(test)), k=1)
    pred = ltrain[np.asarray(res.ids)[:, 0]]
    print(f"1NN label prediction accuracy: {float(np.mean(pred == ltest)):.3f} "
          f"(paper Table V: 0.837 on real OCR)")

    # collision probability sanity: empirical vs Laplacian kernel
    x, y = jnp.asarray(train[0]), jnp.asarray(train[0]) + 0.05
    emp = float(jnp.mean((rbh.hash_points(params, x) == rbh.hash_points(params, y)).astype(jnp.float32)))
    theo = float(rbh.kernel(x, y, sigma))
    print(f"collision prob: empirical {emp:.3f} vs kernel {theo:.3f}")


if __name__ == "__main__":
    main()
