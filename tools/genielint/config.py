"""Lint configuration: per-rule scopes, allowlists, and budgets.

Every allowlist entry here is a *documented design decision*, not an escape
hatch -- each one names the contract it carves out and why the carve-out is
sound (docs/CONTRACTS.md holds the long-form rationale).  One-off local
exemptions use the inline ``# genielint: ignore[rule]`` syntax instead, so
blanket suppressions never accumulate silently in config.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Knobs and allowlists consumed by the rules (tools/genielint/rules_*).

    Paths are repo-relative POSIX paths under the scan root (``src/``), e.g.
    ``repro/core/plan.py``; prefixes end with ``/``.
    """

    # -- executor-sovereignty ----------------------------------------------
    # The only modules allowed to *call* the selection/merge/pad-mask
    # machinery: the executor itself plus the modules that define it.
    # Everything else must delegate through core/plan.execute.
    executor_modules: frozenset = frozenset({
        "repro/core/plan.py",    # the executor: the one orchestration site
        "repro/core/select.py",  # defines select_topk (method dispatch)
        "repro/core/cpq.py",     # defines topk_from_candidates + CPQ select
        "repro/core/spq.py",     # SPQ selection method (calls the CPQ merge)
        "repro/core/merge.py",   # defines merge_ragged / merge_topk
    })
    # The call names whose call sites the rule governs.
    governed_calls: frozenset = frozenset({
        "select_topk", "merge_ragged", "merge_topk",
        "_mask_pad_counts", "_mask_invalid", "topk_from_candidates",
    })

    # -- pallas-kernel-contract --------------------------------------------
    kernel_prefix: str = "repro/kernels/"
    # VMEM is ~16 MiB/core on current TPUs; the budget leaves headroom for
    # Pallas' double-buffered input windows and scratch.  Configurable via
    # --vmem-budget-mb.
    vmem_budget_bytes: int = 12 * 1024 * 1024
    # Conservative stand-in for tile dims the resolver cannot fold to a
    # constant (data-dependent widths like the signature length m): GENIE
    # signature/feature widths are <= 512 everywhere (configs/, packing
    # word counts are 32x smaller still).
    assume_dim: int = 512
    # The registry's count-dtype policy (core/engines.py::MatchModel): match
    # kernels accumulate and emit exact int32 counts; any narrowing happens
    # *after* the kernel via as_count_dtype (Bitmap-Counter, paper III-C).
    # A float out_shape reintroduces the 2^24 rounding bound PR 6 removed
    # from the cosine kernel.  tests/test_lint.py cross-checks this set
    # against the live registry policy.
    kernel_out_dtypes: frozenset = frozenset({"int32"})

    # -- retrace-hygiene ----------------------------------------------------
    # Modules whose jitted/kernel function bodies must stay retrace-free:
    # the executor and every Pallas kernel module.
    traced_modules: frozenset = frozenset({"repro/core/plan.py"})
    traced_prefixes: tuple = ("repro/kernels/",)
    # QueryPlan fields that legitimately do not appear verbatim in
    # describe(): each is derived from fields that DO appear, so a cache-key
    # change is still always visible in the description.
    describe_derived: frozenset = frozenset({
        "match",      # resolved from engine x use_kernel x signature_layout
                      # x tile_overrides (core/autotune.py tuned tiles bind
                      # memoized callables; overrides surface verbatim)
        "params",     # expanded into the k / method / use_kernel keys
        "pad_value",  # resolved from engine x signature_layout
    })

    # -- lock-discipline ----------------------------------------------------
    lock_modules: frozenset = frozenset({
        "repro/serve/frontend.py",
        "repro/serve/scheduler.py",
        "repro/serve/metrics.py",
    })

    # -- wall-clock ----------------------------------------------------------
    # time.time() is banned for durations; fault-tolerance heartbeats keep it
    # BY DESIGN -- deadlines are compared across processes on the same
    # machine, and perf_counter's epoch is process-local (PR 8 comment in
    # runtime/fault_tolerance.py).
    wall_clock_allow: frozenset = frozenset({
        "repro/runtime/fault_tolerance.py",
    })

    # -- broad-except --------------------------------------------------------
    # No file-level allowlist: the two by-design catch-alls (the dry-run's
    # record-the-bug-loudly boundary, the serving dispatch loop's
    # scatter-don't-die boundary) carry inline ignores at the site, where
    # the justification lives next to the code.
    broad_except_allow: frozenset = frozenset()


DEFAULT = LintConfig()
