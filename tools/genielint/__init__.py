"""genielint: AST-based invariant checker for the GENIE codebase.

The contracts GENIE's correctness rests on -- one selection path through the
executor, sound Pallas kernel tiling/dtypes, retrace-free executor code,
lock-guarded serving state, monotonic duration clocks -- are invisible to
the type system and were previously enforced by parity suites plus one
string-grep test.  This package enforces them mechanically at the AST level
so contract drift fails CI in seconds instead of recurring PR-over-PR.

Usage:
    python -m tools.genielint [--json reports/lint.json] [paths...]

Every enforced invariant is documented in docs/CONTRACTS.md, along with the
`# genielint: ignore[rule]` suppression syntax and a walkthrough for adding
new rules.
"""
from tools.genielint.config import LintConfig  # noqa: F401
from tools.genielint.core import (ALL_RULES, Finding, lint_file,  # noqa: F401
                                  run_lint)
