"""CLI: ``python -m tools.genielint [--json reports/lint.json] [paths...]``.

Lints every .py under ``src/`` (or just the given paths, resolved against
the scan root) with all registered rules.  Prints one line per finding,
writes the machine-readable report when ``--json`` is given, and exits
non-zero iff any finding is unsuppressed -- so the CI lane (tools/ci.sh,
first lane) fails fast on a contract violation before any device work.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

from tools.genielint.config import DEFAULT
from tools.genielint.core import ALL_RULES, _load_rules, run_lint, write_json

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def main(argv=None) -> int:
    _load_rules()
    ap = argparse.ArgumentParser(
        prog="python -m tools.genielint",
        description="AST-based invariant checker for the "
                    "registry->planner->executor spine, Pallas kernel "
                    "contracts, and serving lock discipline "
                    "(docs/CONTRACTS.md).")
    ap.add_argument("paths", nargs="*",
                    help="files to lint (relative to --root); default: "
                         "every .py under --root")
    ap.add_argument("--root", default=os.path.join(_REPO, "src"),
                    help="scan root; rule scopes are paths relative to it "
                         "(default: <repo>/src)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="also write the findings report to this path")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to run "
                         f"(available: {', '.join(sorted(ALL_RULES))})")
    ap.add_argument("--vmem-budget-mb", type=float, default=None,
                    help="override the pallas-kernel-contract VMEM tile "
                         "budget (default: "
                         f"{DEFAULT.vmem_budget_bytes // (1024 * 1024)} MiB)")
    args = ap.parse_args(argv)

    config = DEFAULT
    if args.vmem_budget_mb is not None:
        config = dataclasses.replace(
            config, vmem_budget_bytes=int(args.vmem_budget_mb * 1024 * 1024))
    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = sorted(set(rules) - set(ALL_RULES))
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(unknown)} "
                     f"(available: {', '.join(sorted(ALL_RULES))})")

    findings = run_lint(args.root, files=args.paths or None,
                        config=config, rules=rules)
    for f in findings:
        print(f.format())
    if args.json_path:
        write_json(findings, args.json_path)

    unsuppressed = [f for f in findings if not f.suppressed]
    n_sup = len(findings) - len(unsuppressed)
    tail = f" ({n_sup} suppressed)" if n_sup else ""
    if unsuppressed:
        print(f"genielint: {len(unsuppressed)} finding(s){tail}")
        return 1
    print(f"genielint: clean{tail} "
          f"({len(rules or ALL_RULES)} rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
