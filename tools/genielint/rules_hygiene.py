"""Rules: wall-clock and broad-except.

wall-clock
    ``time.time()`` is wall-clock: NTP steps it, VMs freeze it, and a
    duration computed from two wall-clock reads can come out negative.
    Every duration in the repo (benchmarks, dry-run cost probes, serving
    latencies) must use ``time.perf_counter()``.  The one carve-out is
    runtime/fault_tolerance.py (config.wall_clock_allow): its heartbeat
    deadlines are compared *across processes*, and perf_counter's epoch is
    process-local -- wall-clock is the design there, not an accident.

broad-except
    ``except Exception`` / ``except BaseException`` / bare ``except``
    swallow the bug along with the failure.  Handlers must name the
    failures they expect (the narrowed partition.py and dryrun.py handlers
    are the worked examples).  A catch-all that is genuinely the design --
    a dispatch loop that must scatter errors to futures rather than die, a
    record-the-bug-loudly boundary -- carries an inline
    ``# genielint: ignore[broad-except]`` at the site, where the
    justification lives next to the code.
"""
from __future__ import annotations

import ast
from typing import Iterable

from tools.genielint.config import LintConfig
from tools.genielint.core import (Finding, LintModule, dotted_name,
                                  register)

_BROAD = {"Exception", "BaseException"}


@register("wall-clock")
def check_wall_clock(module: LintModule,
                     config: LintConfig) -> Iterable[Finding]:
    if module.relpath in config.wall_clock_allow:
        return
    # `from time import time` makes a bare time() call wall-clock too
    bare_time = any(
        isinstance(node, ast.ImportFrom) and node.module == "time"
        and any(alias.name == "time" for alias in node.names)
        for node in ast.walk(module.tree))
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name == "time.time" or (bare_time and name == "time"):
            yield Finding(
                rule="wall-clock", path=module.relpath,
                line=node.lineno, col=node.col_offset,
                message=("time.time() is wall-clock (NTP can step it; "
                         "deltas can go negative) -- use "
                         "time.perf_counter() for durations; cross-process "
                         "deadlines belong in runtime/fault_tolerance.py"))


@register("broad-except")
def check_broad_except(module: LintModule,
                       config: LintConfig) -> Iterable[Finding]:
    if module.relpath in config.broad_except_allow:
        return
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            caught = "bare except"
        else:
            types = node.type.elts if isinstance(node.type, ast.Tuple) \
                else [node.type]
            broad = [t for t in types
                     if (dotted_name(t) or "").split(".")[-1] in _BROAD]
            if not broad:
                continue
            caught = f"except {dotted_name(broad[0])}"
        yield Finding(
            rule="broad-except", path=module.relpath,
            line=node.lineno, col=node.col_offset,
            message=(f"{caught} swallows bugs along with the expected "
                     f"failure: name the exceptions this boundary "
                     f"anticipates, or -- if catching everything IS the "
                     f"design -- justify it at the site with "
                     f"# genielint: ignore[broad-except]"))
