"""Rule: retrace-hygiene.

The executor (core/plan.py) and the Pallas kernel modules are the hot,
trace-once code: a stray Python coercion or branch on a traced value either
crashes (ConcretizationTypeError) or -- worse -- silently bakes a
data-dependent constant into the compiled program.  And the plan cache is
only sound if a `QueryPlan`'s identity captures everything that changes the
compiled shape.  Three checks:

  1. `int()` / `float()` / `bool()` coercions inside jitted/kernel function
     bodies are flagged unless the argument is static shape math
     (contains `.shape`) or a literal.
  2. `if` / `while` tests referencing a jitted function's own parameters
     (the traced operands) are flagged; `x is None` / `x is not None` tests
     stay legal (operand *presence* is static at trace time).
  3. `QueryPlan` must stay a frozen dataclass (the plan IS the
     executable-cache key), no field may opt out via
     ``field(hash=False/compare=False)``, and every field must surface in
     `describe()` -- either verbatim or through a documented derived key
     (config.describe_derived) -- so cost reports never hide a cache axis.

Traced functions are discovered, not declared: defs decorated with
``jax.jit`` / ``functools.partial(jax.jit, ...)``, defs passed to
``jax.jit(fn)``, and kernel bodies handed to ``pl.pallas_call`` (directly or
through ``functools.partial``).
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from tools.genielint.config import LintConfig
from tools.genielint.core import (Finding, LintModule, call_name,
                                  dotted_name, register)

RULE = "retrace-hygiene"
_COERCIONS = {"int", "float", "bool"}


def _in_scope(module: LintModule, config: LintConfig) -> bool:
    return (module.relpath in config.traced_modules
            or module.relpath.startswith(tuple(config.traced_prefixes)))


# ---------------------------------------------------------------------------
# Traced-function discovery
# ---------------------------------------------------------------------------

def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit, or functools.partial(jax.jit, ...)."""
    name = dotted_name(node)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call) and call_name(node) == "partial" and node.args:
        return dotted_name(node.args[0]) in ("jax.jit", "jit")
    return False


def _partial_target(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Call) and call_name(node) == "partial" and node.args:
        return dotted_name(node.args[0])
    return None


def traced_function_names(tree: ast.AST) -> set[str]:
    names: set[str] = set()
    # local name -> wrapped function name, for `kernel = partial(_f, ...)`
    partial_of: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            target = _partial_target(node.value)
            if target:
                partial_of[node.targets[0].id] = target
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_expr(d) for d in node.decorator_list):
                names.add(node.name)
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in ("jax.jit", "jit") and node.args \
                    and isinstance(node.args[0], ast.Name):
                names.add(node.args[0].id)
            if fname and fname.endswith("pallas_call") and node.args:
                first = node.args[0]
                target = _partial_target(first)
                if target:
                    names.add(target)
                elif isinstance(first, ast.Name):
                    names.add(partial_of.get(first.id, first.id))
    return names


def _is_none_test(test: ast.AST) -> bool:
    """`x is None` / `x is not None` (and `not <that>`): static at trace."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_none_test(test.operand)
    if isinstance(test, ast.Compare) and len(test.ops) == 1:
        if isinstance(test.ops[0], (ast.Is, ast.IsNot)):
            return isinstance(test.comparators[0], ast.Constant) \
                and test.comparators[0].value is None
    return False


def _mentions_shape(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                           "size", "dtype")
               for n in ast.walk(node))


def _check_traced_body(fn: ast.FunctionDef, relpath: str) -> Iterable[Finding]:
    params = {a.arg for a in fn.args.posonlyargs + fn.args.args
              + fn.args.kwonlyargs}
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in _COERCIONS and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Constant) or _mentions_shape(arg):
                continue
            yield Finding(rule=RULE, path=relpath, line=node.lineno,
                          col=node.col_offset, message=(
                              f"{node.func.id}() coercion inside traced "
                              f"function {fn.name!r} concretizes a traced "
                              f"value (or bakes in a host constant); keep "
                              f"coercions on the host side of the jit "
                              f"boundary"))
        if isinstance(node, (ast.If, ast.While)):
            if _is_none_test(node.test):
                continue
            hit = sorted({n.id for n in ast.walk(node.test)
                          if isinstance(n, ast.Name) and n.id in params})
            if hit:
                yield Finding(rule=RULE, path=relpath, line=node.lineno,
                              col=node.col_offset, message=(
                                  f"Python branch on traced parameter(s) "
                                  f"{', '.join(hit)} inside traced function "
                                  f"{fn.name!r}; use lax.cond/jnp.where, or "
                                  f"hoist the decision into the plan"))


# ---------------------------------------------------------------------------
# QueryPlan cache-key / describe() completeness
# ---------------------------------------------------------------------------

def _dataclass_decorator(cls: ast.ClassDef) -> Optional[ast.AST]:
    for dec in cls.decorator_list:
        name = dotted_name(dec.func) if isinstance(dec, ast.Call) \
            else dotted_name(dec)
        if name and name.split(".")[-1] == "dataclass":
            return dec
    return None


def _dec_kw(dec: ast.AST, name: str):
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == name and isinstance(kw.value, ast.Constant):
                return kw.value.value
    return None


def _describe_keys(cls: ast.ClassDef) -> Optional[set[str]]:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == "describe":
            keys: set[str] = set()
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) and call_name(sub) == "dict":
                    keys.update(kw.arg for kw in sub.keywords if kw.arg)
                if isinstance(sub, ast.Dict):
                    keys.update(k.value for k in sub.keys
                                if isinstance(k, ast.Constant)
                                and isinstance(k.value, str))
            return keys
    return None


def _check_queryplan(cls: ast.ClassDef, relpath: str,
                     config: LintConfig) -> Iterable[Finding]:
    where = dict(path=relpath, line=cls.lineno, col=cls.col_offset)
    dec = _dataclass_decorator(cls)
    if dec is None or _dec_kw(dec, "frozen") is not True \
            or _dec_kw(dec, "eq") is False:
        yield Finding(rule=RULE, message=(
            "QueryPlan must be @dataclasses.dataclass(frozen=True): the "
            "plan object IS the executable-cache key, so it must stay "
            "hashable with every field participating"), **where)

    fields: list[tuple[str, ast.AnnAssign]] = []
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            ann = dotted_name(node.annotation) or ""
            if "ClassVar" in ast.dump(node.annotation) or "ClassVar" in ann:
                continue
            fields.append((node.target.id, node))

    for name, node in fields:
        if isinstance(node.value, ast.Call) \
                and call_name(node.value) == "field":
            for kw in node.value.keywords:
                if kw.arg in ("hash", "compare") \
                        and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is False:
                    yield Finding(rule=RULE, path=relpath, line=node.lineno,
                                  col=node.col_offset, message=(
                                      f"QueryPlan field {name!r} opts out of "
                                      f"the cache key ({kw.arg}=False): two "
                                      f"plans differing only here would "
                                      f"collide on one executable"))

    keys = _describe_keys(cls)
    if keys is None:
        yield Finding(rule=RULE, message=(
            "QueryPlan has no describe(); cost reports and dry-runs rely on "
            "it naming every cache axis"), **where)
        return
    for name, node in fields:
        if name not in keys and name not in config.describe_derived:
            yield Finding(rule=RULE, path=relpath, line=node.lineno,
                          col=node.col_offset, message=(
                              f"QueryPlan field {name!r} missing from "
                              f"describe() (and not a documented derived "
                              f"key): every plan-cache axis must be visible "
                              f"in cost reports"))


@register(RULE)
def check(module: LintModule, config: LintConfig) -> Iterable[Finding]:
    if not _in_scope(module, config):
        return
    traced = traced_function_names(module.tree)
    for node in ast.walk(module.tree):
        if isinstance(node, ast.FunctionDef) and node.name in traced:
            yield from _check_traced_body(node, module.relpath)
        if isinstance(node, ast.ClassDef) and node.name == "QueryPlan":
            yield from _check_queryplan(node, module.relpath, config)
