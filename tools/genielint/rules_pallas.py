"""Rule: pallas-kernel-contract.

Every `pl.pallas_call` in `kernels/` must satisfy three statically-checkable
contracts (the FLASH lesson: a tile/dtype mismatch in a fused kernel
corrupts counts silently, it does not crash):

  1. index-map arity == grid rank for every BlockSpec -- a missing/extra
     grid index silently replays or skips tiles.
  2. estimated VMEM tile footprint (sum over in/out specs of
     prod(block dims) x dtype bytes) stays under the configurable budget
     (--vmem-budget-mb).  Dims are folded from module constants, parameter
     defaults, and local shape math; an unresolvable dim (e.g. the
     data-dependent signature width m) conservatively assumes
     `config.assume_dim`.
  3. out_shape dtypes match the MatchModel registry's count-dtype policy
     (exact int32 accumulation; narrowing happens post-kernel via
     as_count_dtype).  A float out_shape reintroduces the 2^24 rounding
     bound PR 6 removed from the cosine kernel.

Also checked: the number of in_specs matches the number of operands the
pallas_call is applied to.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from tools.genielint.config import LintConfig
from tools.genielint.core import (Finding, LintModule, call_name,
                                  const_resolver, dotted_name, parent_map,
                                  register)

RULE = "pallas-kernel-contract"

_DTYPE_BYTES = {
    "int8": 1, "uint8": 1, "bool_": 1,
    "int16": 2, "uint16": 2, "float16": 2, "bfloat16": 2,
    "int32": 4, "uint32": 4, "float32": 4,
    "int64": 8, "uint64": 8, "float64": 8,
}
_FALLBACK_BYTES = 4  # unknown operand dtype: assume a full 4-byte lane


def _module_env(tree: ast.Module) -> dict:
    env: dict[str, int] = {}
    resolve = const_resolver(env)
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            val = resolve(node.value)
            if val is not None:
                env[node.targets[0].id] = val
    return env


def _fn_env(fn: ast.FunctionDef, module_env: dict) -> tuple[dict, dict]:
    """(int env, local tuple assignments) for one kernel-builder function."""
    env = dict(module_env)
    resolve = const_resolver(env)
    args = fn.args
    pos = args.posonlyargs + args.args
    for arg, default in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        val = resolve(default)
        if val is not None:
            env[arg.arg] = val
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            val = resolve(default)
            if val is not None:
                env[arg.arg] = val
    tuples: dict[str, ast.Tuple] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if isinstance(node.value, (ast.Tuple, ast.List)):
                tuples[name] = node.value
            else:
                val = resolve(node.value)
                if val is not None:
                    env[name] = val
    return env, tuples


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _as_sequence(node: ast.AST, tuples: dict) -> list[ast.AST]:
    if isinstance(node, ast.Name) and node.id in tuples:
        node = tuples[node.id]
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return [node]


def _blockspecs(node: Optional[ast.AST], tuples: dict) -> list[ast.Call]:
    if node is None:
        return []
    return [el for el in _as_sequence(node, tuples)
            if isinstance(el, ast.Call) and call_name(el) == "BlockSpec"]


def _dtype_name(node: Optional[ast.AST]) -> Optional[str]:
    """jnp.int32 / np.float32 / "int32" -> "int32"."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    name = dotted_name(node)
    return name.rsplit(".", 1)[-1] if name else None


def _operand_dtype(arg: ast.AST) -> Optional[str]:
    """Dtype of a pallas_call operand when statically evident: the idiomatic
    ``x.astype(jnp.int32)`` cast at the call site."""
    if isinstance(arg, ast.Call) and call_name(arg) == "astype" and arg.args:
        return _dtype_name(arg.args[0])
    return None


def _out_struct_dtypes(node: Optional[ast.AST], tuples: dict) -> list[Optional[str]]:
    out: list[Optional[str]] = []
    if node is None:
        return out
    for el in _as_sequence(node, tuples):
        if isinstance(el, ast.Call) and call_name(el) == "ShapeDtypeStruct":
            dt = el.args[1] if len(el.args) > 1 else _kw(el, "dtype")
            out.append(_dtype_name(dt))
    return out


def _grid_rank(node: Optional[ast.AST], tuples: dict, resolve) -> Optional[int]:
    if node is None:
        return None
    if isinstance(node, ast.Name) and node.id in tuples:
        node = tuples[node.id]
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return 1 if resolve(node) is not None else None


@register(RULE)
def check(module: LintModule, config: LintConfig) -> Iterable[Finding]:
    if not module.relpath.startswith(config.kernel_prefix):
        return
    parents = parent_map(module.tree)
    menv = _module_env(module.tree)

    # map pallas_call -> enclosing function (for env) and -> outer Call (for
    # the operand list: pl.pallas_call(...)(query, data))
    for fn in [n for n in ast.walk(module.tree)
               if isinstance(n, ast.FunctionDef)]:
        env, tuples = _fn_env(fn, menv)
        resolve = const_resolver(env)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and call_name(node) == "pallas_call"):
                continue
            where = dict(path=module.relpath, line=node.lineno,
                         col=node.col_offset)

            grid = _grid_rank(_kw(node, "grid"), tuples, resolve)
            if grid is None:
                yield Finding(rule=RULE, message=(
                    "cannot determine grid rank statically; write grid as a "
                    "literal tuple (or a local tuple assignment)"), **where)

            in_specs = _blockspecs(_kw(node, "in_specs"), tuples)
            out_specs = _blockspecs(_kw(node, "out_specs"), tuples)
            out_dtypes = _out_struct_dtypes(_kw(node, "out_shape"), tuples)

            # operands: the immediately-enclosing call applies the kernel
            outer = parents.get(node)
            operands: list[ast.AST] = []
            if isinstance(outer, ast.Call) and outer.func is node:
                operands = list(outer.args)
                if in_specs and len(operands) != len(in_specs):
                    yield Finding(rule=RULE, message=(
                        f"{len(in_specs)} in_specs but {len(operands)} "
                        f"operands applied to the pallas_call"), **where)

            total_bytes = 0
            assumed = False
            for i, spec in enumerate(in_specs + out_specs):
                # index-map arity vs grid rank
                imap = spec.args[1] if len(spec.args) > 1 \
                    else _kw(spec, "index_map")
                if isinstance(imap, ast.Lambda) and grid is not None:
                    arity = len(imap.args.args)
                    if arity != grid:
                        yield Finding(
                            rule=RULE, path=module.relpath,
                            line=spec.lineno, col=spec.col_offset,
                            message=(f"BlockSpec index_map takes {arity} "
                                     f"indices but the grid has rank {grid}"))
                # tile footprint
                shape = spec.args[0] if spec.args else None
                dims: list[int] = []
                if isinstance(shape, (ast.Tuple, ast.List)):
                    for el in shape.elts:
                        v = resolve(el)
                        if v is None:
                            v = config.assume_dim
                            assumed = True
                        dims.append(v)
                n_in = len(in_specs)
                if i < n_in:
                    dt = _operand_dtype(operands[i]) if i < len(operands) \
                        else None
                else:
                    j = i - n_in
                    dt = out_dtypes[j] if j < len(out_dtypes) else None
                nbytes = _DTYPE_BYTES.get(dt, _FALLBACK_BYTES)
                tile = nbytes
                for d in dims:
                    tile *= d
                total_bytes += tile

            if total_bytes > config.vmem_budget_bytes:
                note = " (unresolved dims assumed " \
                       f"{config.assume_dim})" if assumed else ""
                yield Finding(rule=RULE, message=(
                    f"estimated VMEM tile footprint {total_bytes} bytes "
                    f"exceeds the {config.vmem_budget_bytes}-byte budget"
                    f"{note}; shrink the block shapes or raise "
                    f"--vmem-budget-mb with a rationale"), **where)

            # count-dtype policy on every kernel output
            for dt in out_dtypes:
                if dt is not None and dt not in config.kernel_out_dtypes:
                    yield Finding(rule=RULE, message=(
                        f"out_shape dtype {dt} violates the registry count "
                        f"policy {sorted(config.kernel_out_dtypes)}: kernels "
                        f"emit exact int32 counts; narrowing happens after "
                        f"the kernel via as_count_dtype (a float round-trip "
                        f"caps exactness at 2^24)"), **where)
