"""Rule: executor-sovereignty.

`core/plan.execute` is the ONLY code in the system allowed to orchestrate
selection and merging: match kernels -> pad mask -> select_topk ->
merge_ragged / merge_topk.  Every other entry point (index, segments,
multiload, distributed, serving) must build a `QueryPlan` and delegate, so
the (count desc, id asc) ordering, the pad-never-in-topk mask, and the
ragged per-part k clamp have exactly one implementation.

This replaces the pre-PR 9 string-grep test (tests/test_plan.py) with real
call-site analysis: re-exporting a helper, naming it in a docstring, or
commenting it out no longer trips the check -- *calling* it outside the
executor family does, anywhere under src/, not just in the four legacy
modules the grep watched.
"""
from __future__ import annotations

import ast
from typing import Iterable

from tools.genielint.config import LintConfig
from tools.genielint.core import Finding, LintModule, call_name, register


@register("executor-sovereignty")
def check(module: LintModule, config: LintConfig) -> Iterable[Finding]:
    if module.relpath in config.executor_modules:
        return
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name in config.governed_calls:
            yield Finding(
                rule="executor-sovereignty",
                path=module.relpath, line=node.lineno, col=node.col_offset,
                message=(
                    f"call to {name}() outside the executor family "
                    f"(core/plan.py owns selection/merging/pad-masking; "
                    f"build a QueryPlan and delegate to plan.execute)"
                ),
            )
