"""Rule: lock-discipline.

The serving front-end (serve/frontend.py, scheduler.py, metrics.py) shares
mutable registry/queue/counter state across the caller threads and the
dispatch loop.  The discipline is simple and checkable: an attribute that is
ever *written* under ``with self.<lock>`` is lock-guarded, and lock-guarded
attributes must never be touched -- read or written -- outside a lock
region (``__init__``/``__post_init__`` run before the object is shared and
are exempt).

What counts as a write: ``self.a = ...`` / ``self.a += ...``, subscript
stores ``self.a[k] = ...``, and container-mutator method calls
(``self.a.append(...)``, ``.pop()``, ``.update()``, ...).  Plain method
calls on an attribute (``self._hb.beat(slot)``) are not writes -- the
binding ``self._hb`` itself never changes and the callee owns its own
synchronisation.

Lock-private helpers: a private method whose every intra-class call site
sits inside a lock region inherits the locked context (the fixpoint covers
helpers calling helpers).  This keeps ``FrontendMetrics._tenant`` -- which
writes ``self._tenants`` in its own body but is only ever invoked under
``self._lock`` -- legal without an allowlist entry.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Optional

from tools.genielint.config import LintConfig
from tools.genielint.core import Finding, LintModule, register

RULE = "lock-discipline"

# Mutating container methods: calling one of these on `self.attr` writes the
# guarded state even though `self.attr` itself is only read.
_MUTATORS = {
    "append", "appendleft", "extend", "extendleft", "insert",
    "pop", "popleft", "popitem", "remove", "discard", "clear",
    "update", "setdefault", "add",
}
_EXEMPT_METHODS = {"__init__", "__post_init__"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


@dataclasses.dataclass
class _Access:
    attr: str
    node: ast.AST
    method: str
    locked: bool          # lexically inside `with self.<lock>`
    lock: Optional[str]   # which lock, when locked
    write: bool


@dataclasses.dataclass
class _CallSite:
    callee: str
    method: str
    locked: bool
    node: ast.AST


class _ClassScan:
    """One pass over a class body: lock attrs, accesses, intra-class calls."""

    def __init__(self, cls: ast.ClassDef):
        self.methods = {n.name: n for n in cls.body
                        if isinstance(n, ast.FunctionDef)}
        self.lock_attrs: set[str] = set()
        self.accesses: list[_Access] = []
        self.calls: list[_CallSite] = []
        for fn in self.methods.values():
            for node in ast.walk(fn):
                if isinstance(node, ast.With):
                    for item in node.items:
                        attr = _self_attr(item.context_expr)
                        if attr:
                            self.lock_attrs.add(attr)
        for name, fn in self.methods.items():
            for stmt in fn.body:
                self._visit(stmt, name, locked=False, lock=None)

    def _visit(self, node: ast.AST, method: str, locked: bool,
               lock: Optional[str]) -> None:
        if isinstance(node, ast.With):
            held = [a for item in node.items
                    if (a := _self_attr(item.context_expr))
                    and a in self.lock_attrs]
            for item in node.items:
                self._visit(item.context_expr, method, locked, lock)
            inner = locked or bool(held)
            inner_lock = held[0] if held else lock
            for stmt in node.body:
                self._visit(stmt, method, inner, inner_lock)
            return
        # nested defs inherit the lexical lock context (closures created
        # under the lock may escape it, but none do in the serving layer;
        # a false negative here is acceptable, a false positive is not)
        self._record(node, method, locked, lock)
        for child in ast.iter_child_nodes(node):
            self._visit(child, method, locked, lock)

    def _record(self, node: ast.AST, method: str, locked: bool,
                lock: Optional[str]) -> None:
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                attr = _self_attr(tgt)
                if attr is None and isinstance(tgt, ast.Subscript):
                    attr = _self_attr(tgt.value)
                if attr:
                    self.accesses.append(_Access(attr, node, method, locked,
                                                 lock, write=True))
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            base = _self_attr(node.func.value)
            if base and node.func.attr in _MUTATORS:
                self.accesses.append(_Access(base, node, method, locked,
                                             lock, write=True))
            # intra-class call: self.helper(...)
            owner = _self_attr(node.func)
            if owner in self.methods:
                self.calls.append(_CallSite(owner, method, locked, node))
        if isinstance(node, ast.Attribute) and isinstance(node.ctx, ast.Load):
            attr = _self_attr(node)
            if attr:
                self.accesses.append(_Access(attr, node, method, locked,
                                             lock, write=False))

    def locked_methods(self) -> set[str]:
        """Fixpoint: private methods whose every intra-class call site is in
        a locked context (lexically, or via an already-locked caller)."""
        locked: set[str] = set()
        while True:
            grown = set(locked)
            for name in self.methods:
                if not name.startswith("_") or name in locked:
                    continue
                sites = [c for c in self.calls if c.callee == name]
                if sites and all(c.locked or c.method in locked
                                 for c in sites):
                    grown.add(name)
            if grown == locked:
                return locked
            locked = grown


@register(RULE)
def check(module: LintModule, config: LintConfig) -> Iterable[Finding]:
    if module.relpath not in config.lock_modules:
        return
    for cls in [n for n in ast.walk(module.tree)
                if isinstance(n, ast.ClassDef)]:
        scan = _ClassScan(cls)
        if not scan.lock_attrs:
            continue
        locked_methods = scan.locked_methods()

        def effective(a: _Access) -> bool:
            return a.locked or a.method in locked_methods

        guarded: dict[str, str] = {}    # attr -> the lock that guards it
        for a in scan.accesses:
            if a.write and effective(a) and a.method not in _EXEMPT_METHODS \
                    and a.attr not in scan.lock_attrs:
                guarded.setdefault(a.attr, a.lock or
                                   sorted(scan.lock_attrs)[0])
        seen: set[tuple] = set()
        for a in scan.accesses:
            if a.attr not in guarded or effective(a) \
                    or a.method in _EXEMPT_METHODS:
                continue
            key = (a.attr, a.node.lineno, a.node.col_offset)
            if key in seen:
                continue
            seen.add(key)
            verb = "written" if a.write else "read"
            yield Finding(
                rule=RULE, path=module.relpath,
                line=a.node.lineno, col=a.node.col_offset,
                message=(f"self.{a.attr} is {verb} in "
                         f"{cls.name}.{a.method}() without holding "
                         f"self.{guarded[a.attr]} -- it is written under "
                         f"that lock elsewhere, so every access must hold "
                         f"it (or move into a lock-private helper)"))
