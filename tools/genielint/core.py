"""The genielint rule engine: parse once, run every rule, apply suppressions.

Pure standard library -- the linter never imports jax or repro, so the CI
lane costs milliseconds and runs before any device/toolchain setup.

A rule is a callable ``rule(module: LintModule, config: LintConfig) ->
Iterable[Finding]`` registered in ``ALL_RULES``.  Findings landing on a line
with an inline ``# genielint: ignore[rule-a,rule-b]`` directive (or whose
immediately preceding line is a comment carrying one) are reported as
suppressed and do not fail the run.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Callable, Iterable, Optional

from tools.genielint.config import DEFAULT, LintConfig

_IGNORE_RE = re.compile(r"#\s*genielint:\s*ignore\[([a-z0-9\-_,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a source location."""

    rule: str
    path: str        # repo-relative POSIX path (e.g. repro/core/plan.py)
    line: int        # 1-based
    col: int         # 0-based
    message: str
    suppressed: bool = False

    def format(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}{tag}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class LintModule:
    """One parsed source file plus its suppression directives."""

    def __init__(self, path: str, relpath: str, source: str):
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        # line number -> set of rule names ignored on that line
        self.ignores: dict[int, set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _IGNORE_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.ignores[i] = rules

    def is_suppressed(self, rule: str, line: int) -> bool:
        """A finding is suppressed by a directive on its own line, or by a
        comment-only line directly above it (for lines too long to annotate
        in place)."""
        if rule in self.ignores.get(line, ()):
            return True
        prev = line - 1
        if rule in self.ignores.get(prev, ()):
            text = self.lines[prev - 1].strip() if 0 < prev <= len(self.lines) else ""
            return text.startswith("#")
        return False


# ---------------------------------------------------------------------------
# Shared AST helpers used by the rule modules
# ---------------------------------------------------------------------------

def call_name(node: ast.Call) -> Optional[str]:
    """Terminal name of a call: ``f(...)`` -> "f", ``a.b.f(...)`` -> "f"."""
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """Full dotted path of a Name/Attribute chain, or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def const_resolver(env: dict):
    """Fold an expression of ints over `env` (Name -> int) to a constant.

    Supports the arithmetic that appears in kernel shape math (+ - * // %);
    returns None when any leaf is unknown -- callers substitute a documented
    conservative assumption instead of guessing silently."""

    def resolve(node: ast.AST) -> Optional[int]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return node.value
        if isinstance(node, ast.Name):
            return env.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = resolve(node.operand)
            return None if v is None else -v
        if isinstance(node, ast.BinOp):
            a, b = resolve(node.left), resolve(node.right)
            if a is None or b is None:
                return None
            if isinstance(node.op, ast.Add):
                return a + b
            if isinstance(node.op, ast.Sub):
                return a - b
            if isinstance(node.op, ast.Mult):
                return a * b
            if isinstance(node.op, ast.FloorDiv):
                return a // b if b else None
            if isinstance(node.op, ast.Mod):
                return a % b if b else None
            if isinstance(node.op, ast.Pow):
                return a ** b if 0 <= b < 64 else None
        return None

    return resolve


def parent_map(tree: ast.AST) -> dict:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


# ---------------------------------------------------------------------------
# Registry + runner
# ---------------------------------------------------------------------------

Rule = Callable[[LintModule, LintConfig], Iterable[Finding]]
ALL_RULES: dict[str, Rule] = {}


def register(name: str):
    def deco(fn: Rule) -> Rule:
        ALL_RULES[name] = fn
        return fn
    return deco


# importing the rule modules populates ALL_RULES (import at module bottom so
# the rules can import the helpers above without a cycle)
def _load_rules() -> None:
    from tools.genielint import (rules_hygiene, rules_locks,  # noqa: F401
                                 rules_pallas, rules_retrace, rules_spine)


def iter_py_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return out


def lint_file(path: str, relpath: str,
              config: LintConfig = DEFAULT,
              rules: Optional[Iterable[str]] = None) -> list[Finding]:
    """Run the (selected) rules over one file, suppressions applied."""
    _load_rules()
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        module = LintModule(path, relpath, source)
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=relpath,
                        line=e.lineno or 1, col=e.offset or 0,
                        message=f"cannot parse: {e.msg}")]
    names = list(rules) if rules is not None else list(ALL_RULES)
    findings: list[Finding] = []
    for name in names:
        for f_ in ALL_RULES[name](module, config):
            if module.is_suppressed(f_.rule, f_.line):
                f_ = dataclasses.replace(f_, suppressed=True)
            findings.append(f_)
    findings.sort(key=lambda f_: (f_.path, f_.line, f_.col, f_.rule))
    return findings


def run_lint(root: str, files: Optional[Iterable[str]] = None,
             config: LintConfig = DEFAULT,
             rules: Optional[Iterable[str]] = None) -> list[Finding]:
    """Lint every .py under `root` (or just `files`, resolved against it).

    Rule scopes match on paths relative to `root`, so fixtures laid out
    under a temp root (tests/test_lint.py) see exactly the production
    scoping."""
    paths = [os.path.join(root, f) if not os.path.isabs(f) else f
             for f in files] if files is not None else iter_py_files(root)
    findings: list[Finding] = []
    for path in paths:
        rel = os.path.relpath(path, root)
        findings.extend(lint_file(path, rel, config=config, rules=rules))
    findings.sort(key=lambda f_: (f_.path, f_.line, f_.col, f_.rule))
    return findings


def write_json(findings: list[Finding], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    unsuppressed = [f_ for f_ in findings if not f_.suppressed]
    with open(path, "w", encoding="utf-8") as f:
        json.dump(dict(
            tool="genielint",
            findings=[f_.to_json() for f_ in findings],
            n_findings=len(findings),
            n_unsuppressed=len(unsuppressed),
            ok=not unsuppressed,
        ), f, indent=1)
