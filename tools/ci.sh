#!/usr/bin/env bash
# CI smoke: engine-conformance fast lane, then the tier-1 test suite + one
# quickstart example end-to-end.
#
#   tools/ci.sh            # matrix lane + full tier-1 (ROADMAP.md) + quickstart
#   tools/ci.sh --fast     # matrix lane + GENIE-core test modules + quickstart
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# First lane: static contracts.  Pure-AST (no jax import), so a spine/kernel/
# lock/hygiene violation fails in milliseconds before any device work.
# Contracts + suppression syntax: docs/CONTRACTS.md.
echo "--- genielint (static invariants; docs/CONTRACTS.md) ---"
PYTHONPATH=".:$PYTHONPATH" python -m tools.genielint --json reports/lint.json

# Fast lane: the engine x {reference,kernel} x {search,multiload} conformance
# matrix runs first so an engine-contract break fails in minutes (the
# distributed leg needs a multi-device subprocess and runs with the suite).
echo "--- engine conformance matrix (fast lane) ---"
python -m pytest -q -k "matrix and not distributed" tests/test_engine_matrix.py

echo "--- segment/merge conformance (segmented == monolithic) ---"
python -m pytest -q -k "not distributed" tests/test_segments.py

echo "--- planner parity (execute(plan) == legacy paths, plan-cache hits) ---"
python -m pytest -q -k "not distributed and not sharded_serving" tests/test_plan.py

echo "--- routing conformance (ROUTED_VERIFIED == full scan bit-for-bit) ---"
python -m pytest -q -k "not distributed" tests/test_routing.py

echo "--- serving-frontend parity (coalesced == serial bit-for-bit, 6 engines x routing on/off) ---"
python -m pytest -q -k "parity_matrix or mixed_tenants" tests/test_frontend.py

echo "--- autotuner contracts (tiled-plan parity, cache fallback, plan keying) ---"
python -m pytest -q -k "not tune_end_to_end and not service_tune" tests/test_autotune.py

if [[ "${1:-}" == "--fast" ]]; then
    # (tests/test_plan.py's fast, non-subprocess lane already ran above)
    python -m pytest -x -q \
        tests/test_engines.py tests/test_engine_matrix.py tests/test_cpq.py \
        tests/test_multiload.py tests/test_kernels.py tests/test_system.py
else
    # tier-1 verify command from ROADMAP.md
    python -m pytest -x -q
fi

echo "--- quickstart example ---"
python examples/quickstart.py

echo "--- add-throughput micro-benchmark (BENCH JSON; fails if not flat) ---"
PYTHONPATH=".:$PYTHONPATH" python benchmarks/bench_add_throughput.py

echo "--- serve-latency micro-benchmark (BENCH JSON; cached vs uncached plan) ---"
PYTHONPATH=".:$PYTHONPATH" python benchmarks/bench_serve_latency.py

echo "--- frontend-throughput benchmark (BENCH JSON; batched >= 2x serial gate) ---"
PYTHONPATH=".:$PYTHONPATH" python benchmarks/bench_frontend.py

echo "--- signature-storage roofline (BENCH JSON; packed <= wide/4 gate) ---"
PYTHONPATH=".:$PYTHONPATH" python benchmarks/roofline.py

echo "--- coarse-routing micro-benchmark (BENCH JSON; parity + <50% scanned at recall >= 0.95) ---"
PYTHONPATH=".:$PYTHONPATH" python benchmarks/bench_routing.py

# tiny-budget smoke of the measured autotuner: its main() gates on tuned ==
# default parity, the cache round-trip + fingerprint gate, tuned >= 1.0x on
# at least one engine, and no engine regressing past the noise floor.  The
# full-size acceptance run (>= 1.15x on two engines) is benchmarks/run.py.
echo "--- autotune smoke (BENCH JSON; parity + cache + tuned never regresses) ---"
PYTHONPATH=".:$PYTHONPATH" python -m benchmarks.bench_autotune \
    --n 2048 --q 16 --budget 6 --repeats 2 --engines minsum,tanimoto
echo "CI smoke OK"
