#!/usr/bin/env bash
# CI smoke: tier-1 test suite + one quickstart example end-to-end.
#
#   tools/ci.sh            # full tier-1 (ROADMAP.md) + quickstart
#   tools/ci.sh --fast     # GENIE-core test modules only + quickstart
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [[ "${1:-}" == "--fast" ]]; then
    python -m pytest -x -q \
        tests/test_engines.py tests/test_cpq.py tests/test_multiload.py \
        tests/test_kernels.py tests/test_system.py
else
    # tier-1 verify command from ROADMAP.md
    python -m pytest -x -q
fi

echo "--- quickstart example ---"
python examples/quickstart.py
echo "CI smoke OK"
