from repro.serve.engine import ServeEngine, ServeStats  # noqa: F401
from repro.serve.retrieval import RetrievalService  # noqa: F401
