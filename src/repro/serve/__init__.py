from repro.serve.engine import ServeEngine, ServeStats  # noqa: F401
from repro.serve.frontend import IndexService, ServingFrontend  # noqa: F401
from repro.serve.metrics import FrontendMetrics  # noqa: F401
from repro.serve.retrieval import RetrievalService  # noqa: F401
from repro.serve.scheduler import Overloaded, Request, RequestQueue  # noqa: F401
