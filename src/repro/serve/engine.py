"""Batched serving engine: prefill + greedy/temperature decode over any
registered architecture, with donated KV caches."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import ModelApi


@dataclasses.dataclass
class ServeStats:
    prefill_seconds: float = 0.0
    decode_seconds: float = 0.0
    tokens_generated: int = 0

    @property
    def decode_tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.decode_seconds, 1e-9)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, api: ModelApi, params, *, cache_cap: int = 512):
        self.cfg, self.api, self.params = cfg, api, params
        self.cache_cap = cache_cap
        self._prefill = jax.jit(
            functools.partial(api.prefill, cfg), static_argnames=("cache_cap",)
        )
        self._decode = jax.jit(functools.partial(api.decode_step, cfg), donate_argnums=(2,))

    def generate(self, batch: dict, max_new_tokens: int, *, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0) -> tuple[np.ndarray, ServeStats]:
        if max_new_tokens < 0:
            raise ValueError(f"max_new_tokens must be >= 0, got {max_new_tokens}")
        stats = ServeStats()
        if max_new_tokens == 0:
            # nothing to decode: empty [B, 0] output, zeroed stats, no prefill
            b = jax.tree_util.tree_leaves(batch)[0].shape[0]
            return np.zeros((b, 0), dtype=np.int32), stats
        # perf_counter, not time(): a wall-clock (NTP) step must never record
        # a negative or inflated prefill/decode duration
        t0 = time.perf_counter()
        logits, cache, pos = self._prefill(self.params, batch, cache_cap=self.cache_cap)
        logits.block_until_ready()
        stats.prefill_seconds = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        outs = []
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            if greedy:
                tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            else:
                key, sk = jax.random.split(key)
                tok = jax.random.categorical(sk, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
            logits, cache = self._decode(self.params, tok, cache, pos)
            pos = pos + 1
        jax.block_until_ready(logits)
        stats.decode_seconds = time.perf_counter() - t0
        stats.tokens_generated = max_new_tokens * outs[0].shape[0]
        return np.concatenate(outs, axis=1), stats
