"""GENIE retrieval service: the paper's technique as a first-class serving
feature.

A RetrievalService wraps an embedding function (e.g. mean-pooled hidden
states of any registered LM, or raw feature vectors), an LSH scheme resolved
from the scheme registry (core/lsh/__init__.py), and a SegmentedIndex;
`add`/`search` give tau-ANN document retrieval for retrieval-augmented
serving (examples/serve_batch.py drives it at batch 1024+, the paper's
throughput regime).

Selecting a scheme by name selects the whole engine stack: each LshScheme
names the match engine that consumes its signatures (e2lsh/rbh -> EQ bucket
collisions, minhash -> TANIMOTO sketch collisions, simhash -> COSINE
sign agreements on the MXU) and the MLE that converts match counts back to
similarity estimates, so `RetrievalService(scheme="simhash")` serves
quantized cosine and `scheme="minhash"` serves Jaccard with no other change.

`add` may be called repeatedly: each batch is hashed once and sealed into an
immutable index *segment* (core/segments.py) -- O(batch) device work per
call, no rebuild or re-upload of earlier batches.  When the segment count
exceeds `max_segments` the index compacts adjacent segments down to
`max_segments // 2`, so steady-state search cost stays flat while adds stay
cheap.  Search merges per-segment candidate buffers exactly (segments
partition the object set), so results are identical to a monolithic rebuild.

Sharded serving: pass `mesh=` (a jax device mesh) and `search` plans the
segmented corpus across the mesh via the DISTRIBUTED layout -- segments are
concatenated in global-id order, padded up to mesh divisibility, sharded
over every mesh axis, and served through the same unified executor
(core/plan.py) as single-device search, so results are identical.  The
sharded placement is cached between searches and refreshed only when the
corpus changes (an `add` or a compaction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SegmentedIndex, TopKMethod, distributed
from repro.core import engines as engines_lib
from repro.core import lsh as lsh_lib
from repro.core import plan as plan_lib
from repro.core import routing as routing_lib
from repro.core.lsh import tau_ann
from repro.core.types import SignatureLayout


@dataclasses.dataclass
class RetrievalService:
    embed_fn: Callable[[np.ndarray], np.ndarray]   # raw items -> [n, d] embeddings
    scheme: str = "e2lsh"                          # any registered LshScheme name
    eps: float = 0.06
    delta: float = 0.06
    n_buckets: int = 8192
    w: float = 4.0
    sigma: float = 1.0
    seed: int = 0
    m_override: Optional[int] = None
    max_segments: int = 16                         # compaction trigger for add()
    mesh: Optional[jax.sharding.Mesh] = None       # serve sharded when set
    # signature storage for the sealed segments (core/packing.py): PACKED
    # bit/byte-packs each segment at seal time for engines with a packed
    # format (simhash -> COSINE sign words; minhash -> TANIMOTO uint8 buckets
    # when n_buckets <= 254).  Results are identical to WIDE; only the device
    # footprint and match-phase HBM traffic shrink.
    signature_layout: SignatureLayout | str = SignatureLayout.WIDE
    # measured-knob cache (core/autotune.py): True = the default per-user
    # cache file, a path = that file, an AutotuneCache = itself.  Consulted
    # by every search plan; a miss or a hardware-fingerprint mismatch keeps
    # today's defaults.  Deliberately NOT part of batch_compat_key: the
    # front-end coalesces per tenant and a tenant's autotune spec is fixed
    # for the service's lifetime, so equal keys still share one executable
    # (docs/SERVING.md).
    autotune: object = None

    def __post_init__(self):
        self.m = self.m_override or tau_ann.required_m(self.eps, self.delta)
        if self.max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {self.max_segments}")
        self._scheme = lsh_lib.get_scheme(self.scheme)
        # fail at construction, not at the first add(): WIDE-only engines
        # (e2lsh/rbh -> EQ) reject PACKED here
        self.signature_layout = engines_lib.get(
            self._scheme.engine).require_layout(self.signature_layout)
        self._params = None
        self._dim: Optional[int] = None
        self._index: Optional[SegmentedIndex] = None
        self._items: list = []
        # sharded-serving placement cache: (corpus fingerprint, data, n)
        self._placed: Optional[tuple] = None
        # router cache: (corpus fingerprint, Router) -- invalidated by the
        # same fingerprint that refreshes the sharded placement
        self._routed: Optional[tuple] = None

    def _make_params(self, d: int):
        key = jax.random.PRNGKey(self.seed)
        return self._scheme.make_params(
            key, d=d, m=self.m,
            w=self.w, sigma=self.sigma, n_buckets=self.n_buckets,
        )

    def _hash(self, x: np.ndarray):
        return self._scheme.hash_points(self._params, jnp.asarray(x))

    def _embed(self, items, embeddings: Optional[np.ndarray], expect_rows=None):
        emb = self.embed_fn(items) if embeddings is None else np.asarray(embeddings)
        if emb.ndim != 2:
            raise ValueError(f"embeddings must be [n, d], got shape {emb.shape}")
        if expect_rows is not None and emb.shape[0] != expect_rows:
            raise ValueError(
                f"embeddings row count {emb.shape[0]} != {expect_rows} "
                f"items/queries"
            )
        if self._dim is not None and emb.shape[-1] != self._dim:
            raise ValueError(
                f"embedding dim {emb.shape[-1]} != dim {self._dim} fixed by the "
                f"first add(); the LSH parameters are built once per service"
            )
        return emb

    def add(self, items, embeddings: Optional[np.ndarray] = None) -> None:
        """Add items to the corpus: hashes the batch once and seals it into a
        new index segment (O(batch) device work; earlier segments untouched)."""
        items = list(items)
        if not items:
            raise ValueError("cannot add an empty batch of items")
        emb = self._embed(items, embeddings, expect_rows=len(items))
        if self._params is None:
            self._dim = int(emb.shape[-1])
            self._params = self._make_params(self._dim)
        if self._index is None:
            self._index = SegmentedIndex(engine=self._scheme.engine,
                                         max_count=self.m,
                                         signature_layout=self.signature_layout)
        self._index.add(self._hash(emb))
        self._items.extend(items)
        if len(self._index.segments) > self.max_segments:
            self._index.compact(max(1, self.max_segments // 2))

    def __len__(self) -> int:
        return len(self._items)

    @property
    def index_stats(self):
        """Aggregate IndexStats with per-segment build/compaction accounting."""
        if self._index is None:
            raise ValueError(
                "RetrievalService index is empty (no items added yet): "
                "call add() before reading index_stats"
            )
        return self._index.stats

    def _corpus_fingerprint(self) -> tuple:
        idx = self._index
        return (len(idx.segments), idx.n_objects, idx.compaction_count)

    def _sharded_corpus(self) -> tuple:
        """(sharded data, n_objects), cached until the corpus changes."""
        fp = self._corpus_fingerprint()
        if self._placed is None or self._placed[0] != fp:
            data, n = self._index.concat_data(pad_multiple=self.mesh.size)
            data = jax.device_put(data, distributed.data_sharding(self.mesh))
            self._placed = (fp, data, n)
        return self._placed[1], self._placed[2]

    def _router(self) -> routing_lib.Router:
        """Router over the current segments' summaries, cached until the
        corpus changes (same fingerprint as the sharded placement)."""
        fp = self._corpus_fingerprint()
        if self._routed is None or self._routed[0] != fp:
            self._routed = (fp, self._index.router())
        return self._routed[1]

    def resolve_queries(self, queries, embeddings: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """Materialise and embed one query batch, validating it eagerly:
        iterators are listed before len(), row counts and dims are checked,
        and an empty batch raises a ValueError naming the contract (the
        mirror of the empty-`add()` check) instead of failing downstream
        with a shape error.  The serving front-end (serve/frontend.py) calls
        this on the submitter's thread so bad requests fail synchronously."""
        if queries is not None:
            # materialise iterators/generators before len() -- same contract
            # as add(items); embed_fn receives the list either way
            queries = list(queries)
        eshape = None if embeddings is None else np.shape(embeddings)
        empty = (len(queries) == 0 if queries is not None
                 else bool(eshape) and eshape[0] == 0)
        if empty:
            # checked before embed_fn/shape validation so the caller sees
            # the contract, not a downstream shape error
            raise ValueError(
                "cannot search an empty batch of queries (the mirror of the "
                "empty-add() contract): pass at least one query or embedding "
                "row"
            )
        return self._embed(queries, embeddings,
                           expect_rows=None if queries is None else len(queries))

    def batch_compat_key(self, k: int, method: TopKMethod,
                         routing: routing_lib.Routing | str, *,
                         nprobe: Optional[int] = None,
                         candidate_cap: Optional[int] = None) -> tuple:
        """The coalescing key of a search against this service (core/plan.py
        `batch_compat_key`): two submissions with equal keys reuse one
        cached executable and can stack into one device dispatch.  The
        layout axis is resolved the way `search` will execute -- DISTRIBUTED
        on a mesh-backed service, SEGMENTED otherwise."""
        layout = (plan_lib.Layout.DISTRIBUTED if self.mesh is not None
                  else plan_lib.Layout.SEGMENTED)
        return plan_lib.batch_compat_key(
            self._scheme.engine, layout, self.signature_layout, routing,
            method, k, nprobe=nprobe, candidate_cap=candidate_cap)

    def search(self, queries, k: int = 10, *, embeddings: Optional[np.ndarray] = None,
               method: TopKMethod = TopKMethod.CPQ,
               candidate_cap: Optional[int] = None,
               routing: routing_lib.Routing | str = routing_lib.Routing.NONE,
               nprobe: Optional[int] = None):
        """tau-ANN retrieval over the sealed corpus.

        `routing` plugs the coarse router (core/routing.py) in front of the
        exact match: 'routed' scans only the segments/shards the router
        selects (approximate), 'routed_verified' additionally verifies the
        result threshold against the skipped segments' upper bounds and falls
        back to the full scan when one could still contribute (results then
        bit-for-bit identical to 'none').  Router state is rebuilt whenever
        the corpus fingerprint changes (an add or a compaction)."""
        if self._index is None:
            # a real exception, not an assert: asserts vanish under python -O
            raise ValueError(
                "RetrievalService index is empty (no items added yet): "
                "call add() before search()"
            )
        routing = routing_lib.Routing(routing)
        emb = self.resolve_queries(queries, embeddings)
        qsigs = self._hash(emb)
        if self.mesh is None:
            # the cached per-tenant router (fingerprint-keyed) rides into the
            # segment search, so interleaved add/search only rebuild routing
            # state when the corpus actually changed
            router = (self._router()
                      if routing is not routing_lib.Routing.NONE else None)
            res = self._index.search(qsigs, k=k, method=method,
                                     candidate_cap=candidate_cap,
                                     routing=routing, nprobe=nprobe,
                                     router=router, autotune=self.autotune)
        else:
            # sharded serving: the segmented corpus planned across the mesh
            # via the DISTRIBUTED layout, served by the same executor --
            # results are identical to the single-device segment merge
            data, n = self._sharded_corpus()
            plan = plan_lib.plan_search(
                self._scheme.engine, k, self._index.max_count,
                layout=plan_lib.Layout.DISTRIBUTED, n_objects=n, method=method,
                candidate_cap=candidate_cap,
                use_kernel=self._index.use_kernel,
                mesh_axes=tuple(self.mesh.axis_names),
                signature_layout=self.signature_layout,
                routing=routing, nprobe=nprobe,
                autotune=self.autotune,
                tune_width=int(data.shape[1]),
            )
            model = engines_lib.get(self._scheme.engine)
            # the router scores canonical WIDE queries; the executor gets
            # them packed when the corpus is PACKED
            q_wide = model.prepare_queries(qsigs)
            canonical = q_wide
            if SignatureLayout(self.signature_layout) is SignatureLayout.PACKED:
                canonical = model.pack_queries(q_wide)
            qq = jax.device_put(canonical, distributed.replicated(self.mesh, 2))
            router = (self._router()
                      if routing is not routing_lib.Routing.NONE else None)
            res = plan_lib.execute(plan, data, qq, mesh=self.mesh,
                                   router=router, route_queries=q_wide)
        # scheme-paired MLE: c/m for bucketed families (Eqn 7), the simhash
        # angle inversion for COSINE
        sims = self._scheme.mle(np.asarray(res.counts), self.m)
        return res, sims

    def tune(self, queries, k: int = 10, *,
             embeddings: Optional[np.ndarray] = None,
             method: TopKMethod = TopKMethod.CPQ,
             routing: routing_lib.Routing | str = routing_lib.Routing.NONE,
             budget: int = 32, repeats: int = 3,
             cache=None, save: bool = True):
        """Autotune this service's serving shape against a representative
        query batch (core/autotune.py) and return the winning TunedEntry.

        Measures the part-structured search the unmeshed path actually runs
        -- tile sizes, fused preference, candidate_cap, SEGMENTED vs
        MULTILOAD-host, and (when `routing` is routed) nprobe.  The winner
        lands in `cache` (defaulting to this service's `autotune` spec; an
        in-memory cache is created and installed when neither is set), so
        every later `search` picks the tuned knobs up automatically.
        """
        from repro.core import autotune as autotune_lib

        if self._index is None:
            raise ValueError(
                "RetrievalService index is empty (no items added yet): "
                "call add() before tune()"
            )
        routing = routing_lib.Routing(routing)
        emb = self.resolve_queries(queries, embeddings)
        qsigs = self._hash(emb)
        model = engines_lib.get(self._scheme.engine)
        q_wide = model.prepare_queries(qsigs)
        q_exec = q_wide
        if SignatureLayout(self.signature_layout) is SignatureLayout.PACKED:
            q_exec = model.pack_queries(q_wide)
        stored = jnp.concatenate([s.data for s in self._index.segments], axis=0)
        resolved = autotune_lib.resolve_cache(
            cache if cache is not None else self.autotune)
        if resolved is None:
            resolved = autotune_lib.AutotuneCache()
        entry = autotune_lib.tune(
            model, stored, q_exec, k, self._index.max_count,
            signature_layout=self.signature_layout, method=method,
            part_rows=tuple(self._index.segment_rows),
            router=(self._router()
                    if routing is not routing_lib.Routing.NONE else None),
            routing=routing, budget=budget, repeats=repeats,
            cache=resolved, save=save, prepared=True, route_queries=q_wide,
        )
        if self.autotune is None or self.autotune is False:
            self.autotune = resolved
        return entry

    def items_for(self, result_ids: np.ndarray) -> list:
        """Resolve result ids to the stored items; -1 (empty top-k slots)
        resolve to None.  Ids outside [0, len(self)) raise a ValueError
        naming the offender instead of surfacing an IndexError (or, worse,
        a silently wrong negatively-indexed item)."""
        n = len(self._items)
        rows = np.asarray(result_ids)
        bad = rows[(rows >= n) | (rows < -1)]
        if bad.size:
            # "0..-1" is not a range: name the empty corpus explicitly
            valid = f"valid ids are 0..{n - 1}" if n else "no ids are valid"
            raise ValueError(
                f"items_for: id {int(bad.flat[0])} is outside the corpus "
                f"({n} items indexed; {valid}, or -1 for an empty top-k slot)"
            )
        return [[self._items[int(i)] if i >= 0 else None for i in row] for row in rows]
