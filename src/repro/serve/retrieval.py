"""GENIE retrieval service: the paper's technique as a first-class serving
feature.

A RetrievalService wraps an embedding function (e.g. mean-pooled hidden
states of any registered LM, or raw feature vectors), an LSH scheme resolved
from the scheme registry (core/lsh/__init__.py), and a GenieIndex;
`add`/`search` give tau-ANN document retrieval for retrieval-augmented
serving (examples/serve_batch.py drives it at batch 1024+, the paper's
throughput regime).

Selecting a scheme by name selects the whole engine stack: each LshScheme
names the match engine that consumes its signatures (e2lsh/rbh -> EQ bucket
collisions, minhash -> TANIMOTO sketch collisions, simhash -> COSINE
sign agreements on the MXU) and the MLE that converts match counts back to
similarity estimates, so `RetrievalService(scheme="simhash")` serves
quantized cosine and `scheme="minhash"` serves Jaccard with no other change.

`add` may be called repeatedly: items append to the corpus and the index is
rebuilt over the accumulated signatures (signatures are cached, so only the
new items are hashed).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GenieIndex, TopKMethod
from repro.core import lsh as lsh_lib
from repro.core.lsh import tau_ann


@dataclasses.dataclass
class RetrievalService:
    embed_fn: Callable[[np.ndarray], np.ndarray]   # raw items -> [n, d] embeddings
    scheme: str = "e2lsh"                          # any registered LshScheme name
    eps: float = 0.06
    delta: float = 0.06
    n_buckets: int = 8192
    w: float = 4.0
    sigma: float = 1.0
    seed: int = 0
    m_override: Optional[int] = None

    def __post_init__(self):
        self.m = self.m_override or tau_ann.required_m(self.eps, self.delta)
        self._scheme = lsh_lib.get_scheme(self.scheme)
        self._params = None
        self._index: Optional[GenieIndex] = None
        self._items: list = []
        self._sigs: Optional[jnp.ndarray] = None

    def _make_params(self, d: int):
        key = jax.random.PRNGKey(self.seed)
        return self._scheme.make_params(
            key, d=d, m=self.m,
            w=self.w, sigma=self.sigma, n_buckets=self.n_buckets,
        )

    def _hash(self, x: np.ndarray) -> jnp.ndarray:
        return self._scheme.hash_points(self._params, jnp.asarray(x))

    def add(self, items, embeddings: Optional[np.ndarray] = None) -> None:
        """Add items to the corpus (appends; the index covers every add)."""
        emb = self.embed_fn(items) if embeddings is None else embeddings
        if self._params is None:
            self._params = self._make_params(emb.shape[-1])
        sigs = self._hash(emb)
        self._items.extend(list(items))
        self._sigs = sigs if self._sigs is None else jnp.concatenate(
            [self._sigs, sigs], axis=0)
        self._index = GenieIndex.build(self._scheme.engine, self._sigs,
                                       max_count=self.m)

    def __len__(self) -> int:
        return len(self._items)

    def search(self, queries, k: int = 10, *, embeddings: Optional[np.ndarray] = None,
               method: TopKMethod = TopKMethod.CPQ):
        if self._index is None:
            # a real exception, not an assert: asserts vanish under python -O
            raise ValueError("add() first")
        emb = self.embed_fn(queries) if embeddings is None else embeddings
        qsigs = self._hash(emb)
        res = self._index.search(qsigs, k=k, method=method)
        # scheme-paired MLE: c/m for bucketed families (Eqn 7), the simhash
        # angle inversion for COSINE
        sims = self._scheme.mle(np.asarray(res.counts), self.m)
        return res, sims

    def items_for(self, result_ids: np.ndarray) -> list:
        return [[self._items[int(i)] if i >= 0 else None for i in row] for row in result_ids]
