"""Async multi-tenant serving front-end with continuous batching.

`RetrievalService` is synchronous and single-caller: one thread, one
`search` at a time, one request per device dispatch.  This module is the
serving layer the ROADMAP's "millions of users" north star was gated on --
an asynchronous front-end that accepts concurrent `submit()` calls from many
callers/tenants, coalesces compatible requests into single planner batches
(GENIE's multi-query pass: one device dispatch answers the stacked queries
of every coalesced request), and scatters per-request results back through
futures:

    fe = ServingFrontend(max_wait_us=2000)
    fe.create_tenant("acme", embed_fn=np.asarray, scheme="e2lsh")
    fe.add("acme", items, embeddings=emb)
    fut = fe.submit("acme", None, k=10, embeddings=q)   # returns immediately
    res, sims = fut.result()                            # == serial search

Coalescing is keyed by tenant x `core/plan.batch_compat_key` (engine x
layout x signature_layout x routing x method x k-bucket): requests that
would reuse the same cached executable stack their query rows into one
dispatch, and each request's rows/top-k are sliced back out (with the
stacked rows padded to a power-of-two bucket so steady-state serving reuses
a handful of compiled shapes).  The slice is
bit-for-bit identical to a serial per-request search because every engine's
result order is total ((count desc, id asc)) and per-query independent --
a top-k result is a row-slice and k-prefix of the batched top-k-bucket
result.  The exception is routing='routed' (unverified): its segment
selection is a union over the query batch, so results are batch-dependent
by contract -- exactly as they already are for multi-query
`RetrievalService.search` calls; use 'routed_verified' for bit-exact routed
serving.

Multi-tenancy: each tenant owns its corpus (a `RetrievalService`, or any
backend with the same search surface -- see `IndexService` for raw
`SegmentedIndex` tenants) while sharing one front-end, one dispatch loop,
one plan cache, and -- when `mesh=` is set -- one device mesh: every
tenant's segmented corpus is placed onto the same shared mesh, with the
per-tenant router and sharded-placement caches living inside each tenant's
service (refreshed only when that tenant's corpus fingerprint changes).

Admission control bounds queue depth (`max_queue`, shed with a typed
`Overloaded`) and batch-assembly wait (`max_wait_us` / `max_batch`), and
tenant lifecycle reuses the fault-tolerance heartbeats
(runtime/fault_tolerance.py): every submit/add beats the tenant's slot,
`idle_tenants()` surfaces tenants whose heartbeat expired, and
`drain(tenant)` stops admission, waits for in-flight work, and releases the
tenant's caches cleanly.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future
from typing import Any, Optional

import numpy as np

from repro.core import TopKMethod
from repro.core import plan as plan_lib
from repro.core import routing as routing_lib
from repro.core.segments import SegmentedIndex
from repro.core.types import TopKResult
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.serve.metrics import FrontendMetrics
from repro.serve.retrieval import RetrievalService
from repro.serve.scheduler import Overloaded, Request, RequestQueue


@dataclasses.dataclass
class IndexService:
    """Minimal front-end backend over a raw `SegmentedIndex`: pre-hashed
    signatures in, `TopKResult` out, no LSH scheme or MLE (`sims` is None).
    Gives every registered engine -- including the ones without an LSH
    scheme (RANGE/MINSUM/IP) -- a front-end tenant surface.

    `query_adapter` unstacks engines whose native query form is not a single
    array: RANGE queries are an (lo, hi) pair, so callers submit them stacked
    as [q, 2, d] with `query_adapter=lambda a: (a[:, 0, :], a[:, 1, :])` --
    coalescing concatenates the stacked form along axis 0 and the adapter
    restores the engine's form at dispatch time."""

    index: SegmentedIndex
    query_adapter: Optional[Any] = None

    def add(self, items=None, embeddings=None) -> None:
        self.index.add(items if embeddings is None else embeddings)

    def resolve_queries(self, queries, embeddings=None):
        sigs = np.asarray(queries if embeddings is None else embeddings)
        if sigs.ndim < 2:
            raise ValueError(f"query signatures must be [q, ...], got "
                             f"shape {sigs.shape}")
        if sigs.shape[0] == 0:
            raise ValueError("cannot search an empty batch of queries")
        return sigs

    def batch_compat_key(self, k: int, method, routing, *,
                         nprobe=None, candidate_cap=None) -> tuple:
        return plan_lib.batch_compat_key(
            self.index.engine, plan_lib.Layout.SEGMENTED,
            self.index.signature_layout, routing, method, k,
            nprobe=nprobe, candidate_cap=candidate_cap)

    def search(self, queries, k: int = 10, *, embeddings=None,
               method=TopKMethod.CPQ, candidate_cap=None,
               routing=routing_lib.Routing.NONE, nprobe=None):
        sigs = self.resolve_queries(queries, embeddings)
        if self.query_adapter is not None:
            sigs = self.query_adapter(sigs)
        res = self.index.search(sigs, k=k, method=method,
                                candidate_cap=candidate_cap,
                                routing=routing, nprobe=nprobe)
        return res, None


@dataclasses.dataclass
class _Tenant:
    """Registry entry: the backend plus its serving bookkeeping."""

    name: str
    service: Any
    slot: int                    # heartbeat slot (fault_tolerance monitor)
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    draining: bool = False
    pending: int = 0             # admitted requests not yet completed


class ServingFrontend:
    """The async serving loop: queue -> coalesce -> plan -> scatter.

    Knobs (admission control / batching):
      max_queue        queued-request bound; beyond it `submit` sheds with
                       `Overloaded` instead of growing latency unboundedly.
      max_batch        stacked query rows per device dispatch.
      max_wait_us      batch-assembly wait: the oldest queued request waits
                       at most this long for companions before dispatch.
      heartbeat_timeout_s / max_tenants
                       tenant-liveness monitor (runtime/fault_tolerance.py).
    """

    def __init__(self, *, mesh=None, max_queue: int = 256,
                 max_batch: int = 1024, max_wait_us: int = 2000,
                 heartbeat_timeout_s: float = 60.0, max_tenants: int = 64,
                 metrics_window: int = 2048, start: bool = True):
        if max_tenants < 1:
            raise ValueError(f"max_tenants must be >= 1, got {max_tenants}")
        if max_wait_us < 0:
            raise ValueError(f"max_wait_us must be >= 0, got {max_wait_us}")
        self.mesh = mesh
        self._queue = RequestQueue(max_queue=max_queue, max_batch=max_batch,
                                   max_wait_s=max_wait_us * 1e-6)
        self._metrics = FrontendMetrics(window=metrics_window)
        self._hb = HeartbeatMonitor(n_hosts=max_tenants,
                                    timeout_s=heartbeat_timeout_s)
        self._tenants: dict[str, _Tenant] = {}
        self._free_slots = list(range(max_tenants))
        self._reg = threading.Condition()   # tenant registry + pending waits
        self._seq = itertools.count()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        """Start the dispatch loop (idempotent; `start=False` constructions
        call this once their tenants are registered)."""
        if self._stop.is_set():
            raise RuntimeError("frontend is closed; build a new one")
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._loop,
                                            name="serving-frontend",
                                            daemon=True)
            self._thread.start()

    def close(self) -> None:
        """Stop admission, drain every admitted request, stop the loop."""
        self._stop.set()
        self._queue.wake()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "ServingFrontend":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    # Tenants
    # ------------------------------------------------------------------
    def register(self, name: str, service: Any) -> Any:
        """Register a tenant backend (a `RetrievalService`, `IndexService`,
        or anything with the same add/resolve_queries/batch_compat_key/
        search surface).  Returns the service for chaining."""
        for attr in ("add", "search", "resolve_queries", "batch_compat_key"):
            if not callable(getattr(service, attr, None)):
                raise TypeError(
                    f"tenant backend must provide {attr}(); "
                    f"{type(service).__name__} does not")
        with self._reg:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} is already registered")
            if not self._free_slots:
                raise Overloaded(
                    f"tenant capacity exhausted ({len(self._tenants)} "
                    f"registered, max_tenants reached): cannot register "
                    f"{name!r}", tenant=name)
            slot = self._free_slots.pop()
            self._tenants[name] = _Tenant(name=name, service=service, slot=slot)
            self._hb.beat(slot)
        return service

    def create_tenant(self, name: str, **retrieval_kwargs) -> RetrievalService:
        """Build and register a `RetrievalService` tenant on the shared
        mesh (keyword args go to the RetrievalService constructor)."""
        svc = RetrievalService(mesh=self.mesh, **retrieval_kwargs)
        return self.register(name, svc)

    def _tenant(self, name: str, *, for_submit: bool = False) -> _Tenant:
        with self._reg:
            t = self._tenants.get(name)
            if t is None:
                raise KeyError(f"unknown tenant {name!r}; registered: "
                               f"{sorted(self._tenants)}")
            if for_submit and t.draining:
                raise ValueError(f"tenant {name!r} is draining: no new "
                                 f"requests admitted")
            return t

    def add(self, tenant: str, items, embeddings=None) -> None:
        """Grow a tenant's corpus.  Serialised against that tenant's
        in-flight dispatches (per-tenant lock), so a dispatch observes the
        corpus either before or after the add, never mid-mutation; the
        tenant's own router/placement caches refresh on the next search
        via the corpus fingerprint."""
        t = self._tenant(tenant, for_submit=True)
        with t.lock:
            t.service.add(items, embeddings=embeddings)
        self._hb.beat(t.slot)

    def tenants(self) -> list[str]:
        with self._reg:
            return sorted(self._tenants)

    def idle_tenants(self, now: Optional[float] = None) -> list[str]:
        """Tenants whose heartbeat (last submit/add) expired -- candidates
        for `drain()`.  `now` is wall-clock (time.time), forwarded to the
        fault-tolerance monitor for deterministic tests."""
        with self._reg:
            dead = set(self._hb.dead(now))
            return sorted(n for n, t in self._tenants.items()
                          if t.slot in dead)

    def drain(self, tenant: str, timeout: Optional[float] = None) -> None:
        """Cleanly remove a tenant: stop admitting its requests, wait for
        its admitted work to complete, then release its slot, caches, and
        metrics.  Raises TimeoutError if in-flight work outlives `timeout`."""
        t = self._tenant(tenant)
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._reg:
            t.draining = True
            while t.pending > 0:
                remaining = None if deadline is None \
                    else deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"drain({tenant!r}): {t.pending} requests still "
                        f"in flight after {timeout}s")
                self._reg.wait(timeout=remaining)
            self._tenants.pop(tenant, None)
            self._free_slots.append(t.slot)
        self._metrics.forget_tenant(tenant)

    def reap_idle(self, now: Optional[float] = None,
                  timeout: Optional[float] = None) -> list[str]:
        """Drain every heartbeat-expired tenant; returns the drained names."""
        idle = self.idle_tenants(now)
        for name in idle:
            self.drain(name, timeout=timeout)
        return idle

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, tenant: str, queries=None, k: int = 10, *,
               embeddings=None, method: TopKMethod = TopKMethod.CPQ,
               routing: routing_lib.Routing | str = routing_lib.Routing.NONE,
               nprobe: Optional[int] = None,
               candidate_cap: Optional[int] = None) -> Future:
        """Submit one search; returns a `Future` resolving to the same
        `(TopKResult, sims)` pair `RetrievalService.search` returns (numpy
        arrays, sliced out of the coalesced dispatch).  Validation (unknown
        tenant, empty/missized query batches, draining tenants, queue-full
        `Overloaded`) happens synchronously on the caller's thread.  The
        future carries the request-order id as `.request_seq`."""
        if self._stop.is_set():
            raise RuntimeError("frontend is closed: submit rejected")
        t = self._tenant(tenant, for_submit=True)
        method = TopKMethod(method)
        routing = routing_lib.Routing(routing)
        emb = t.service.resolve_queries(queries, embeddings)
        key = (tenant, t.service.batch_compat_key(
            k, method, routing, nprobe=nprobe, candidate_cap=candidate_cap))
        dispatch_k = int(k) if candidate_cap is not None else plan_lib.k_bucket(k)
        fut: Future = Future()
        req = Request(
            seq=next(self._seq), tenant=tenant, embeddings=emb, k=int(k),
            dispatch_k=dispatch_k, method=method, routing=routing,
            nprobe=nprobe, candidate_cap=candidate_cap, key=key, future=fut,
            submitted_at=time.perf_counter(),
        )
        fut.request_seq = req.seq
        with self._reg:
            t.pending += 1
        try:
            depth = self._queue.offer(req)
        except Overloaded:
            with self._reg:
                t.pending -= 1
                self._reg.notify_all()
            self._metrics.record_shed(tenant)
            raise
        self._hb.beat(t.slot)
        self._metrics.record_submit(tenant, req.n_queries)
        self._metrics.record_queue_depth(depth)
        return fut

    def search(self, tenant: str, queries=None, k: int = 10, **kw):
        """Synchronous convenience: `submit(...).result()`."""
        return self.submit(tenant, queries, k, **kw).result()

    def stats(self) -> dict:
        """Metrics snapshot (serve/metrics.py schema) plus registry state."""
        snap = self._metrics.snapshot()
        with self._reg:
            snap["registered_tenants"] = sorted(self._tenants)
            snap["pending_requests"] = sum(t.pending
                                           for t in self._tenants.values())
        return snap

    # ------------------------------------------------------------------
    # Dispatch loop: queue -> coalesce -> plan -> scatter
    # ------------------------------------------------------------------
    def _loop(self) -> None:
        while True:
            groups = self._queue.take(self._stop)
            if groups is None:      # stopped and fully drained
                return
            self._metrics.record_queue_depth(self._queue.depth())
            for group in groups:
                self._dispatch(group)

    def _dispatch(self, group: list[Request]) -> None:
        """One coalesced device dispatch: stack the group's query rows, run
        the tenant's search at the shared bucketed k, slice per-request
        results back out, resolve futures.  A failure resolves every future
        in the group exceptionally; the loop itself never dies."""
        first = group[0]
        try:
            t = self._tenant(first.tenant)
            stacked = group[0].embeddings if len(group) == 1 else \
                np.concatenate([np.asarray(r.embeddings) for r in group], axis=0)
            rows = int(np.shape(stacked)[0])
            # query-row bucketing: pad the stacked batch to the next power of
            # two so steady-state serving cycles through O(log max_batch)
            # compiled shapes instead of tracing a fresh executable per
            # distinct pile-up size.  Padding rows are copies of row 0 and
            # are sliced away below -- every engine's match/select/merge is
            # per-query independent, so real rows are unaffected (the same
            # argument that makes the k-bucket slice bit-exact).
            pad = plan_lib.k_bucket(rows) - rows
            if pad:
                stacked = np.concatenate(
                    [stacked, np.repeat(np.asarray(stacked[:1]), pad, axis=0)],
                    axis=0)
            with t.lock:
                res, sims = t.service.search(
                    None, k=first.dispatch_k, embeddings=stacked,
                    method=first.method, routing=first.routing,
                    nprobe=first.nprobe, candidate_cap=first.candidate_cap)
            ids = np.asarray(res.ids)
            counts = np.asarray(res.counts)
            sims_np = None if sims is None else np.asarray(sims)
            done = time.perf_counter()
            lo = 0
            for req in group:
                hi = lo + req.n_queries
                rcnt = counts[lo:hi, :req.k]
                out = TopKResult(ids=ids[lo:hi, :req.k], counts=rcnt,
                                 threshold=rcnt[:, -1])
                rsims = None if sims_np is None else sims_np[lo:hi, :req.k]
                self._metrics.record_completion(req.tenant,
                                                done - req.submitted_at)
                req.future.set_result((out, rsims))
                lo = hi
            self._metrics.record_dispatch(len(group), lo)
        # Scatter boundary: whatever a dispatch raises (including
        # KeyboardInterrupt mid-device-call) must resolve the group's
        # futures exceptionally -- a dead dispatch loop would hang every
        # waiting caller forever.
        # genielint: ignore[broad-except]
        except BaseException as e:  # noqa: BLE001 -- scatter, don't die
            for req in group:
                if not req.future.done():
                    req.future.set_exception(e)
        finally:
            with self._reg:
                for req in group:
                    tt = self._tenants.get(req.tenant)
                    if tt is not None:
                        tt.pending -= 1
                self._reg.notify_all()
