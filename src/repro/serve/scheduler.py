"""Continuous-batching scheduler: the queue/admission/coalescing policy of
the serving front-end (serve/frontend.py).

GENIE's device-side strength is the multi-query pass -- one inverted-index
scan answers a whole query batch (PAPER.md's multi-query processing) -- so
the serving problem is entirely host-side: accept concurrent requests from
many callers, hold them just long enough to assemble a fat batch, and hand
compatible requests to one device dispatch.  This module owns that policy,
deterministically and without touching the device:

  * `Request` -- one submitted search: resolved query embeddings, the
    request-order id (`seq`), the per-request top-k, and the coalescing key
    (tenant x `core/plan.batch_compat_key`).  Its `future` resolves to the
    per-request result.
  * `RequestQueue.offer` -- admission control: a bounded queue that sheds
    load with a typed `Overloaded` error instead of queueing unboundedly
    (the caller sees backpressure immediately; the device never does).
  * `RequestQueue.take` -- batch assembly: blocks for the first request,
    then waits at most `max_wait_s` (measured from the *oldest* queued
    request, so no request's assembly wait exceeds the knob) or until
    `max_batch` query rows are queued, drains everything, and groups it.
  * `coalesce` -- groups drained requests by coalescing key in arrival
    order and chunks each group so one dispatch never stacks more than
    `max_batch` query rows (a single oversized request still dispatches
    alone -- requests are never split across dispatches).

The scheduler never inspects engines or plans; compatibility is entirely
encoded in the key the front-end computed at submit time.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Optional


class Overloaded(RuntimeError):
    """Load shed by admission control: the request was rejected, not queued.

    Carries the shedding context so callers (and tests) can tell which
    bound tripped without parsing the message."""

    def __init__(self, message: str, *, tenant: Optional[str] = None,
                 queue_depth: Optional[int] = None,
                 max_queue: Optional[int] = None):
        super().__init__(message)
        self.tenant = tenant
        self.queue_depth = queue_depth
        self.max_queue = max_queue


@dataclasses.dataclass
class Request:
    """One submitted search, resolved and validated at submit time."""

    seq: int                      # request-order id (global, monotonic)
    tenant: str
    embeddings: Any               # resolved query rows [q, ...]
    k: int                        # the caller's top-k (result width)
    dispatch_k: int               # the bucketed k the dispatch runs at
    method: Any
    routing: Any
    nprobe: Optional[int]
    candidate_cap: Optional[int]
    key: tuple                    # (tenant, batch_compat_key) coalescing key
    future: Future
    submitted_at: float           # perf_counter at admission

    @property
    def n_queries(self) -> int:
        return int(self.embeddings.shape[0])


def coalesce(requests: list[Request], max_batch: int) -> list[list[Request]]:
    """Group drained requests by coalescing key, preserving arrival order
    within and across groups (groups are ordered by their oldest member).
    Each group is chunked so its stacked query rows stay <= `max_batch`;
    a single request larger than `max_batch` dispatches alone."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    by_key: dict[tuple, list[Request]] = {}
    for req in sorted(requests, key=lambda r: r.seq):
        by_key.setdefault(req.key, []).append(req)
    groups: list[list[Request]] = []
    for members in by_key.values():
        chunk: list[Request] = []
        rows = 0
        for req in members:
            if chunk and rows + req.n_queries > max_batch:
                groups.append(chunk)
                chunk, rows = [], 0
            chunk.append(req)
            rows += req.n_queries
        if chunk:
            groups.append(chunk)
    groups.sort(key=lambda g: g[0].seq)
    return groups


class RequestQueue:
    """Bounded, condition-guarded request queue with batch-assembly waits.

    `max_queue` bounds *requests* queued (admission), `max_batch` bounds
    *query rows* per dispatch (coalescing), `max_wait_s` bounds how long the
    oldest queued request waits for companions before dispatch."""

    def __init__(self, max_queue: int = 256, max_batch: int = 1024,
                 max_wait_s: float = 0.002):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        self.max_queue = int(max_queue)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._cond = threading.Condition()
        self._q: list[Request] = []

    def depth(self) -> int:
        with self._cond:
            return len(self._q)

    def offer(self, req: Request) -> int:
        """Admit a request or shed it with `Overloaded`.  Returns the queue
        depth after admission (for the metrics gauge)."""
        with self._cond:
            if len(self._q) >= self.max_queue:
                raise Overloaded(
                    f"serving queue full ({len(self._q)}/{self.max_queue} "
                    f"requests): request for tenant {req.tenant!r} shed",
                    tenant=req.tenant, queue_depth=len(self._q),
                    max_queue=self.max_queue,
                )
            self._q.append(req)
            depth = len(self._q)
            self._cond.notify_all()
        return depth

    def wake(self) -> None:
        """Nudge a blocked `take` (used by frontend shutdown)."""
        with self._cond:
            self._cond.notify_all()

    def take(self, stop: threading.Event) -> Optional[list[list[Request]]]:
        """Block for work, assemble a batch, drain, and coalesce.

        Returns the coalesced groups, or None when `stop` is set and the
        queue is fully drained (the dispatch loop's exit signal).  When
        `stop` is set with requests still queued they are returned for a
        final graceful drain -- shutdown never abandons admitted work."""
        with self._cond:
            while not self._q:
                if stop.is_set():
                    return None
                self._cond.wait(timeout=0.05)
            if not stop.is_set() and self.max_wait_s > 0:
                deadline = self._q[0].submitted_at + self.max_wait_s
                while (sum(r.n_queries for r in self._q) < self.max_batch
                       and not stop.is_set()):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
            drained = self._q
            self._q = []
        return coalesce(drained, self.max_batch)
