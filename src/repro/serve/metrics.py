"""Serving-front-end metrics: per-tenant latency percentiles, batch
occupancy, coalesce ratio, queue depth.

The front-end (serve/frontend.py) is judged on exactly the numbers Johnson
et al.'s billion-scale serving work tracks -- tail latency and device
occupancy under concurrent load -- so this module records them where they
happen (submit / shed / dispatch / completion) behind one lock and exposes
a consistent snapshot through `FrontendMetrics.snapshot()`, which
`ServingFrontend.stats()` re-exports and `benchmarks/bench_frontend.py`
gates in CI.

Everything here is host-side bookkeeping: a bounded per-tenant latency
window (so a long-lived serving process cannot grow without bound), plain
counters for requests/queries/sheds, and per-dispatch occupancy samples.
Percentiles use the nearest-rank method on the retained window -- cheap,
deterministic, and exact for the window it describes.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Optional


def percentile(samples, p: float) -> float:
    """Nearest-rank percentile of `samples` (p in [0, 100]); 0.0 on empty."""
    if not samples:
        return 0.0
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile must be in [0, 100], got {p}")
    ordered = sorted(samples)
    rank = min(len(ordered) - 1, max(0, int(round(p / 100.0 * (len(ordered) - 1)))))
    return float(ordered[rank])


@dataclasses.dataclass
class _TenantCounters:
    """One tenant's running totals plus its bounded latency window."""

    submitted: int = 0          # requests admitted to the queue
    shed: int = 0               # requests rejected by admission control
    queries: int = 0            # query rows admitted
    dispatched: int = 0         # requests that completed through a dispatch
    latencies_s: collections.deque = None  # submit -> result, bounded window

    def __post_init__(self):
        if self.latencies_s is None:
            self.latencies_s = collections.deque(maxlen=2048)


class FrontendMetrics:
    """Thread-safe recorder for the serving front-end.

    `window` bounds the retained latency samples per tenant (and the global
    occupancy window): percentiles describe the most recent `window`
    completions, not all-time history.
    """

    def __init__(self, window: int = 2048):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = int(window)
        self._lock = threading.Lock()
        self._tenants: dict[str, _TenantCounters] = {}
        self._dispatches = 0                 # device dispatches issued
        self._dispatched_requests = 0        # requests served by them
        self._dispatched_queries = 0         # query rows served by them
        self._occupancy = collections.deque(maxlen=self.window)  # queries/dispatch
        self._queue_depth = 0
        self._queue_high_water = 0

    # -- recording hooks (called by frontend/scheduler) --------------------
    def _tenant(self, name: str) -> _TenantCounters:
        t = self._tenants.get(name)
        if t is None:
            t = self._tenants[name] = _TenantCounters(
                latencies_s=collections.deque(maxlen=self.window))
        return t

    def record_submit(self, tenant: str, n_queries: int) -> None:
        with self._lock:
            t = self._tenant(tenant)
            t.submitted += 1
            t.queries += int(n_queries)

    def record_shed(self, tenant: str) -> None:
        with self._lock:
            self._tenant(tenant).shed += 1

    def record_queue_depth(self, depth: int) -> None:
        with self._lock:
            self._queue_depth = int(depth)
            self._queue_high_water = max(self._queue_high_water, int(depth))

    def record_dispatch(self, n_requests: int, n_queries: int) -> None:
        """One coalesced device dispatch serving `n_requests` requests whose
        stacked query batch held `n_queries` rows."""
        with self._lock:
            self._dispatches += 1
            self._dispatched_requests += int(n_requests)
            self._dispatched_queries += int(n_queries)
            self._occupancy.append(int(n_queries))

    def record_completion(self, tenant: str, latency_s: float) -> None:
        """One request's submit -> result latency (recorded per request, so
        tenant percentiles weight requests, not dispatches)."""
        with self._lock:
            t = self._tenant(tenant)
            t.dispatched += 1
            t.latencies_s.append(float(latency_s))

    def forget_tenant(self, tenant: str) -> None:
        """Drop a drained tenant's counters (serve/frontend.py drain())."""
        with self._lock:
            self._tenants.pop(tenant, None)

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Consistent point-in-time view: global coalescing/occupancy/queue
        numbers plus per-tenant request counters and latency percentiles
        (milliseconds; 0.0 before any completion)."""
        with self._lock:
            per_tenant = {}
            all_lat: list[float] = []
            for name, t in sorted(self._tenants.items()):
                lat = list(t.latencies_s)
                all_lat.extend(lat)
                per_tenant[name] = dict(
                    submitted=t.submitted,
                    shed=t.shed,
                    queries=t.queries,
                    completed=t.dispatched,
                    p50_ms=round(percentile(lat, 50) * 1e3, 3),
                    p99_ms=round(percentile(lat, 99) * 1e3, 3),
                )
            occ = list(self._occupancy)
            return dict(
                dispatches=self._dispatches,
                requests_dispatched=self._dispatched_requests,
                queries_dispatched=self._dispatched_queries,
                # >1 means the front-end is actually coalescing: requests
                # per device dispatch
                coalesce_ratio=round(
                    self._dispatched_requests / self._dispatches, 3)
                if self._dispatches else 0.0,
                # mean stacked-query rows per dispatch over the window
                batch_occupancy=round(sum(occ) / len(occ), 3) if occ else 0.0,
                queue_depth=self._queue_depth,
                queue_high_water=self._queue_high_water,
                p50_ms=round(percentile(all_lat, 50) * 1e3, 3),
                p99_ms=round(percentile(all_lat, 99) * 1e3, 3),
                tenants=per_tenant,
            )
