"""Deterministic synthetic data pipeline.

Provides reproducible token / embedding batches keyed by (seed, step, shard)
so every host in a multi-host job can independently materialise its shard of
the global batch (no cross-host data service needed), and a restart resumes
bit-identically from the checkpointed step cursor -- the data-side half of
fault tolerance.

The token stream is a Zipfian unigram mixture with in-sequence structure
(short Markov motifs), enough signal for loss-goes-down end-to-end tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticTokens:
    """Deterministic, seekable token batches."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        probs = 1.0 / ranks**1.1
        self.probs = probs / probs.sum()

    def batch(self, step: int) -> dict:
        d = self.data
        rng = np.random.default_rng((d.seed, step, d.host_id))
        toks = rng.choice(self.cfg.vocab, size=(d.host_batch, d.seq_len), p=self.probs)
        # motif structure: token t+1 = (token t + 1) % V with prob .5
        copy = rng.random((d.host_batch, d.seq_len)) < 0.5
        for j in range(1, d.seq_len):
            toks[:, j] = np.where(copy[:, j], (toks[:, j - 1] + 1) % self.cfg.vocab, toks[:, j])
        out = {"tokens": toks.astype(np.int32)}
        if self.cfg.family == "vlm":
            p = self.cfg.n_patches
            out["patch_embeds"] = rng.standard_normal(
                (d.host_batch, p, self.cfg.d_model)).astype(np.float32) * 0.02
            out["tokens"] = out["tokens"][:, : d.seq_len - p]
        if self.cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (d.host_batch, d.seq_len, self.cfg.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def synthetic_points(n: int, dim: int, n_clusters: int = 32, seed: int = 0,
                     cluster_std: float = 0.3) -> tuple[np.ndarray, np.ndarray]:
    """Clustered points for the GENIE ANN experiments (labels = cluster id,
    the OCR-style 1NN-prediction ground truth)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim)) * 2.0
    labels = rng.integers(0, n_clusters, n)
    pts = centers[labels] + rng.standard_normal((n, dim)) * cluster_std
    return pts.astype(np.float32), labels.astype(np.int32)


def synthetic_sequences(n: int, length: int = 40, alphabet: str = "abcdefghij",
                        seed: int = 0) -> list[str]:
    """Random sequences (DBLP-title stand-ins)."""
    rng = np.random.default_rng(seed)
    a = np.array(list(alphabet))
    return ["".join(a[rng.integers(0, len(a), length)]) for _ in range(n)]


def mutate_sequence(s: str, rate: float, alphabet: str = "abcdefghij", seed: int = 0) -> str:
    """Paper section VI-A1: modify `rate` fraction of characters."""
    rng = np.random.default_rng(seed)
    chars = list(s)
    k = int(round(rate * len(chars)))
    idx = rng.choice(len(chars), size=k, replace=False)
    for i in idx:
        chars[i] = alphabet[rng.integers(0, len(alphabet))]
    return "".join(chars)


def synthetic_documents(n: int, vocab_words: int = 5000, words_per_doc: int = 12,
                        seed: int = 0) -> list[str]:
    """Short documents (Tweets stand-ins), Zipfian word choice."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_words + 1, dtype=np.float64)
    probs = (1.0 / ranks**1.05); probs /= probs.sum()
    docs = []
    for _ in range(n):
        ids = rng.choice(vocab_words, size=words_per_doc, p=probs)
        docs.append(" ".join(f"w{int(i)}" for i in ids))
    return docs
