"""GenieIndex: the user-facing GENIE index (paper sections II-III).

Holds device-resident transformed data (signatures / count vectors / binary
vectors / discretized tuples) and resolves *everything* engine-specific --
data preparation, query canonicalisation, kernel-vs-reference match dispatch,
index statistics, count-domain bounds -- through the MatchModel registry
(core/engines.py).  Top-k selection goes through the shared `select_topk`
pipeline (core/select.py) for every path: single-device, multiload streaming,
and the distributed step in core/distributed.py.

    index = GenieIndex.build(Engine.EQ, sigs)            # generic builder
    index = GenieIndex.build_lsh(sigs, max_count=m)      # named alias
    result = index.search(query_sigs, k=100)             # TopKResult

Larger-than-memory data uses `search_multiload` (all registered engines);
multi-device search goes through core.distributed (the index there is just
the sharded data matrix plus an Engine name).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engines as _engines
from repro.core import multiload as _multiload
from repro.core.select import select_topk
from repro.core.types import Engine, IndexStats, SearchParams, TopKMethod, TopKResult


@dataclasses.dataclass
class GenieIndex:
    engine: Engine
    max_count: int
    data: jnp.ndarray                      # EQ: sigs [N,m]; MINSUM: counts [N,V];
    data_hi: Optional[jnp.ndarray] = None  # unused (reserved for interval data)
    stats: IndexStats = dataclasses.field(default_factory=IndexStats)
    use_kernel: bool = True

    # ------------------------------------------------------------------
    # Builders
    # ------------------------------------------------------------------
    @classmethod
    def build(cls, engine: Engine | str, data, max_count: int | None = None,
              use_kernel: bool = True) -> "GenieIndex":
        """Generic builder: any registered engine, one code path.

        `max_count` defaults to the engine's derived count bound (e.g. m for
        EQ, #attributes for RANGE); engines without a derivable bound
        (MINSUM, IP) require it explicitly.
        """
        model = _engines.get(engine)
        t0 = time.time()
        arr = model.prepare_data(data)
        stats = model.build_stats(arr)
        # block: prepare_data dispatches async jnp ops; without this the
        # timer reports dispatch time, not build time
        jax.block_until_ready(arr)
        stats.build_seconds = time.time() - t0
        return cls(engine=model.engine,
                   max_count=model.resolve_max_count(arr, max_count),
                   data=arr, stats=stats, use_kernel=use_kernel)

    # Thin named aliases kept for API compatibility with existing callers.
    @classmethod
    def build_lsh(cls, signatures, max_count: int | None = None, use_kernel: bool = True):
        """EQ engine over LSH signatures int32 [N, m]."""
        return cls.build(Engine.EQ, signatures, max_count=max_count, use_kernel=use_kernel)

    @classmethod
    def build_minsum(cls, count_vectors, max_count: int, use_kernel: bool = True):
        """MINSUM engine over n-gram count vectors int [N, V]."""
        return cls.build(Engine.MINSUM, count_vectors, max_count=max_count,
                         use_kernel=use_kernel)

    @classmethod
    def build_ip(cls, binary_vectors, max_count: int, use_kernel: bool = True):
        """IP engine over binary word vectors [N, V]."""
        return cls.build(Engine.IP, binary_vectors, max_count=max_count,
                         use_kernel=use_kernel)

    @classmethod
    def build_relational(cls, discrete_tuples, use_kernel: bool = True):
        """RANGE engine over discretized tuples int32 [N, d]."""
        return cls.build(Engine.RANGE, discrete_tuples, use_kernel=use_kernel)

    @classmethod
    def build_tanimoto(cls, minhash_sigs, max_count: int | None = None,
                       use_kernel: bool = True):
        """TANIMOTO engine over minhash sketches int32 [N, m]."""
        return cls.build(Engine.TANIMOTO, minhash_sigs, max_count=max_count,
                         use_kernel=use_kernel)

    @classmethod
    def build_cosine(cls, vectors, max_count: int | None = None,
                     use_kernel: bool = True):
        """COSINE engine over raw vectors [N, V] (sign-quantized at build)."""
        return cls.build(Engine.COSINE, vectors, max_count=max_count,
                         use_kernel=use_kernel)

    # ------------------------------------------------------------------
    # Matching + selection
    # ------------------------------------------------------------------
    @property
    def model(self) -> _engines.MatchModel:
        return _engines.get(self.engine)

    def match_counts(self, queries) -> jnp.ndarray:
        """counts int32 [Q, N] under this index's engine."""
        return self.model.match_counts(self.data, queries, self.use_kernel)

    def search(self, queries, k: int, method: TopKMethod = TopKMethod.CPQ,
               candidate_cap: int | None = None) -> TopKResult:
        params = SearchParams(k=k, max_count=self.max_count, method=method,
                              candidate_cap=candidate_cap, use_kernel=self.use_kernel)
        counts = self.match_counts(queries)
        return select_topk(counts, params, use_fused_hist=self.use_kernel)

    def search_multiload(self, queries, k: int, n_parts: int,
                         method: TopKMethod = TopKMethod.CPQ) -> TopKResult:
        """Paper section III-D: split this index into parts and stream them.

        Works for every registered engine: parts are padded with the engine's
        neutral fill and pad rows are masked out of the merged result.
        """
        if n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {n_parts}")
        model = self.model
        n = self.stats.n_objects
        part = -(-n // n_parts)
        pad = part * n_parts - n
        data = self.data
        if pad:
            fill = jnp.full((pad,) + data.shape[1:], model.pad_value, dtype=data.dtype)
            data = jnp.concatenate([data, fill], axis=0)
        chunks = data.reshape(n_parts, part, *data.shape[1:])
        params = SearchParams(k=k, max_count=self.max_count, method=method,
                              use_kernel=self.use_kernel)
        return _multiload.multiload_search(
            chunks, model.prepare_queries(queries), params,
            model.match_fn(use_kernel=self.use_kernel), n_objects=n,
        )
