"""GenieIndex: the user-facing GENIE index (paper sections II-III).

Holds device-resident transformed data (signatures / count vectors / binary
vectors / discretized tuples) and resolves *everything* engine-specific --
data preparation, query canonicalisation, kernel-vs-reference match dispatch,
index statistics, count-domain bounds -- through the MatchModel registry
(core/engines.py).  Searches are thin adapters over the unified planner
(core/plan.py): `search` builds a MONOLITHIC QueryPlan, `search_multiload`
a MULTILOAD plan, and both delegate to the one executor that owns match
dispatch, pad masking, top-k selection, and merging (docs/EXECUTION.md).

    index = GenieIndex.build(Engine.EQ, sigs)            # generic builder
    index = GenieIndex.build_lsh(sigs, max_count=m)      # named alias
    result = index.search(query_sigs, k=100)             # TopKResult

Larger-than-memory data uses `search_multiload` (all registered engines);
multi-device search goes through core.distributed (the index there is just
the sharded data matrix plus an Engine name).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engines as _engines
from repro.core import plan as _plan
from repro.core import routing as _routing
from repro.core.types import (Engine, IndexStats, SignatureLayout,
                              TopKMethod, TopKResult)


@dataclasses.dataclass
class GenieIndex:
    engine: Engine
    max_count: int
    data: jnp.ndarray                      # EQ: sigs [N,m]; MINSUM: counts [N,V];
    data_hi: Optional[jnp.ndarray] = None  # unused (reserved for interval data)
    stats: IndexStats = dataclasses.field(default_factory=IndexStats)
    use_kernel: bool = True
    # storage format of `data` (core/packing.py); PACKED indexes hold the
    # bit/byte-packed array and dispatch the packed match kernels
    signature_layout: SignatureLayout = SignatureLayout.WIDE
    # routing summary over the *wide* prepared array (core/routing.py),
    # computed at seal time; None for indexes assembled outside build()
    summary: Optional[_routing.SegmentSummary] = None

    # ------------------------------------------------------------------
    # Builders
    # ------------------------------------------------------------------
    @classmethod
    def build(cls, engine: Engine | str, data, max_count: int | None = None,
              use_kernel: bool = True,
              signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
              ) -> "GenieIndex":
        """Generic builder: any registered engine, one code path.

        `max_count` defaults to the engine's derived count bound (e.g. m for
        EQ, #attributes for RANGE); engines without a derivable bound
        (MINSUM, IP) require it explicitly.

        `signature_layout=PACKED` packs the prepared array once at seal time
        (COSINE signs -> uint32-word bitfields, TANIMOTO bucket ids -> uint8)
        for engines with a packed format; counts and top-k results are
        bit-for-bit identical to WIDE, only the device footprint and HBM
        traffic shrink.
        """
        model = _engines.get(engine)
        layout = model.require_layout(signature_layout)
        # perf_counter, not time(): a wall-clock (NTP) step must never record
        # a negative build duration
        t0 = time.perf_counter()
        arr = model.prepare_data(data)
        # stats, postings, the count bound, and the routing summary all read
        # the *logical* WIDE shape -- resolve them before packing (the packed
        # array's width is words/bytes, not signature slots)
        stats = model.build_stats(arr)
        summary = _routing.summarize(model.engine, arr)
        max_count = model.resolve_max_count(arr, max_count)
        if layout is SignatureLayout.PACKED:
            arr = model.pack_data(arr)
            stats.signature_layout = layout.value
            stats.bytes_device = int(arr.size) * arr.dtype.itemsize
        # block: prepare_data dispatches async jnp ops; without this the
        # timer reports dispatch time, not build time
        jax.block_until_ready(arr)
        stats.build_seconds = time.perf_counter() - t0
        return cls(engine=model.engine, max_count=max_count,
                   data=arr, stats=stats, use_kernel=use_kernel,
                   signature_layout=layout, summary=summary)

    # Thin named aliases kept for API compatibility with existing callers.
    @classmethod
    def build_lsh(cls, signatures, max_count: int | None = None, use_kernel: bool = True):
        """EQ engine over LSH signatures int32 [N, m]."""
        return cls.build(Engine.EQ, signatures, max_count=max_count, use_kernel=use_kernel)

    @classmethod
    def build_minsum(cls, count_vectors, max_count: int, use_kernel: bool = True):
        """MINSUM engine over n-gram count vectors int [N, V]."""
        return cls.build(Engine.MINSUM, count_vectors, max_count=max_count,
                         use_kernel=use_kernel)

    @classmethod
    def build_ip(cls, binary_vectors, max_count: int, use_kernel: bool = True):
        """IP engine over binary word vectors [N, V]."""
        return cls.build(Engine.IP, binary_vectors, max_count=max_count,
                         use_kernel=use_kernel)

    @classmethod
    def build_relational(cls, discrete_tuples, use_kernel: bool = True):
        """RANGE engine over discretized tuples int32 [N, d]."""
        return cls.build(Engine.RANGE, discrete_tuples, use_kernel=use_kernel)

    @classmethod
    def build_tanimoto(cls, minhash_sigs, max_count: int | None = None,
                       use_kernel: bool = True,
                       signature_layout: SignatureLayout | str = SignatureLayout.WIDE):
        """TANIMOTO engine over minhash sketches int32 [N, m]."""
        return cls.build(Engine.TANIMOTO, minhash_sigs, max_count=max_count,
                         use_kernel=use_kernel, signature_layout=signature_layout)

    @classmethod
    def build_cosine(cls, vectors, max_count: int | None = None,
                     use_kernel: bool = True,
                     signature_layout: SignatureLayout | str = SignatureLayout.WIDE):
        """COSINE engine over raw vectors [N, V] (sign-quantized at build)."""
        return cls.build(Engine.COSINE, vectors, max_count=max_count,
                         use_kernel=use_kernel, signature_layout=signature_layout)

    # ------------------------------------------------------------------
    # Matching + selection
    # ------------------------------------------------------------------
    @property
    def model(self) -> _engines.MatchModel:
        return _engines.get(self.engine)

    def prepare_queries(self, queries):
        """Raw queries -> canonical pytree in this index's signature layout."""
        return self.model.prepare_queries_for(queries, self.signature_layout)

    def match_counts(self, queries) -> jnp.ndarray:
        """counts int32 [Q, N] under this index's engine."""
        return self.model.match_counts(self.data, queries, self.use_kernel,
                                       self.signature_layout)

    def search(self, queries, k: int, method: TopKMethod = TopKMethod.CPQ,
               candidate_cap: int | None = None,
               tile_overrides=None, autotune=None) -> TopKResult:
        plan = _plan.plan_search(
            self.engine, k, self.max_count, layout=_plan.Layout.MONOLITHIC,
            part_rows=(self.stats.n_objects,), method=method,
            candidate_cap=candidate_cap, use_kernel=self.use_kernel,
            signature_layout=self.signature_layout,
            tile_overrides=tile_overrides, autotune=autotune,
            tune_width=int(self.data.shape[1]),
        )
        return _plan.execute(plan, self.data, self.prepare_queries(queries))

    def search_multiload(self, queries, k: int, n_parts: int,
                         method: TopKMethod = TopKMethod.CPQ,
                         candidate_cap: int | None = None,
                         tile_overrides=None, autotune=None) -> TopKResult:
        """Paper section III-D: split this index into parts and stream them.

        Works for every registered engine: the planned layout pads parts with
        the engine's neutral fill and the executor masks pad rows out of the
        merged result.
        """
        plan = _plan.plan_search(
            self.engine, k, self.max_count, layout=_plan.Layout.MULTILOAD,
            n_parts=n_parts, n_objects=self.stats.n_objects, method=method,
            candidate_cap=candidate_cap, use_kernel=self.use_kernel,
            signature_layout=self.signature_layout,
            tile_overrides=tile_overrides, autotune=autotune,
            tune_width=int(self.data.shape[1]),
        )
        chunks = _plan.pad_and_stack(plan, self.data)
        return _plan.execute(plan, chunks, self.prepare_queries(queries))
