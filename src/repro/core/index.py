"""GenieIndex: the user-facing GENIE index (paper sections II-III).

Holds device-resident transformed data (signatures / count vectors / binary
vectors / discretized tuples), dispatches the match-count computation to the
Pallas kernels (or the pure-jnp engines), and selects top-k with c-PQ
(default), SPQ, or full sort.

    index = GenieIndex.build_lsh(sigs, max_count=m)
    result = index.search(query_sigs, k=100)            # TopKResult

Large-than-memory data uses `search_multiload`; multi-device search goes
through core.distributed (the index there is just the sharded signature
matrix).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import cpq as _cpq
from repro.core import match as _match
from repro.core import multiload as _multiload
from repro.core import spq as _spq
from repro.core.types import Engine, IndexStats, SearchParams, TopKMethod, TopKResult


@dataclasses.dataclass
class GenieIndex:
    engine: Engine
    max_count: int
    data: jnp.ndarray                      # EQ: sigs [N,m]; MINSUM: counts [N,V];
    data_hi: Optional[jnp.ndarray] = None  # unused (reserved for interval data)
    stats: IndexStats = dataclasses.field(default_factory=IndexStats)
    use_kernel: bool = True

    # ------------------------------------------------------------------
    # Builders
    # ------------------------------------------------------------------
    @classmethod
    def build_lsh(cls, signatures, max_count: int | None = None, use_kernel: bool = True):
        """EQ engine over LSH signatures int32 [N, m]."""
        t0 = time.time()
        sigs = jnp.asarray(signatures, dtype=jnp.int32)
        n, m = sigs.shape
        stats = IndexStats(
            n_objects=n, n_lists=m, total_postings=n * m,
            bytes_device=sigs.size * 4, build_seconds=time.time() - t0,
        )
        return cls(engine=Engine.EQ, max_count=max_count or m, data=sigs,
                   stats=stats, use_kernel=use_kernel)

    @classmethod
    def build_minsum(cls, count_vectors, max_count: int, use_kernel: bool = True):
        """MINSUM engine over n-gram count vectors int [N, V]."""
        t0 = time.time()
        cv = jnp.asarray(count_vectors, dtype=jnp.int32)
        stats = IndexStats(
            n_objects=cv.shape[0], n_lists=cv.shape[1],
            total_postings=int(np.asarray(jnp.sum(cv))),
            bytes_device=cv.size * 4, build_seconds=time.time() - t0,
        )
        return cls(engine=Engine.MINSUM, max_count=max_count, data=cv,
                   stats=stats, use_kernel=use_kernel)

    @classmethod
    def build_ip(cls, binary_vectors, max_count: int, use_kernel: bool = True):
        """IP engine over binary word vectors [N, V]."""
        t0 = time.time()
        bv = jnp.asarray(binary_vectors)
        stats = IndexStats(
            n_objects=bv.shape[0], n_lists=bv.shape[1],
            total_postings=int(np.asarray(jnp.sum(bv.astype(jnp.int32)))),
            bytes_device=bv.size * bv.dtype.itemsize, build_seconds=time.time() - t0,
        )
        return cls(engine=Engine.IP, max_count=max_count, data=bv,
                   stats=stats, use_kernel=use_kernel)

    @classmethod
    def build_relational(cls, discrete_tuples, use_kernel: bool = True):
        """RANGE engine over discretized tuples int32 [N, d]."""
        t0 = time.time()
        x = jnp.asarray(discrete_tuples, dtype=jnp.int32)
        stats = IndexStats(
            n_objects=x.shape[0], n_lists=x.shape[1], total_postings=x.size,
            bytes_device=x.size * 4, build_seconds=time.time() - t0,
        )
        return cls(engine=Engine.RANGE, max_count=x.shape[1], data=x,
                   stats=stats, use_kernel=use_kernel)

    # ------------------------------------------------------------------
    # Matching + selection
    # ------------------------------------------------------------------
    def match_counts(self, queries) -> jnp.ndarray:
        """counts int32 [Q, N] under this index's engine."""
        if self.use_kernel:
            from repro.kernels import ops as kops

            if self.engine == Engine.EQ:
                return kops.match_count(self.data, jnp.asarray(queries, jnp.int32))
            if self.engine == Engine.RANGE:
                lo, hi = queries
                return kops.range_count(self.data, jnp.asarray(lo), jnp.asarray(hi))
            if self.engine == Engine.MINSUM:
                return kops.minsum_count(self.data, jnp.asarray(queries, jnp.int32))
            if self.engine == Engine.IP:
                return kops.ip_count(self.data, jnp.asarray(queries))
        else:
            if self.engine == Engine.EQ:
                return _match.match_eq(self.data, jnp.asarray(queries, jnp.int32))
            if self.engine == Engine.RANGE:
                lo, hi = queries
                return _match.match_range(self.data, jnp.asarray(lo), jnp.asarray(hi))
            if self.engine == Engine.MINSUM:
                return _match.match_minsum(self.data, jnp.asarray(queries, jnp.int32))
            if self.engine == Engine.IP:
                return _match.match_ip(self.data, jnp.asarray(queries))
        raise ValueError(f"unknown engine {self.engine}")

    def search(self, queries, k: int, method: TopKMethod = TopKMethod.CPQ,
               candidate_cap: int | None = None) -> TopKResult:
        params = SearchParams(k=k, max_count=self.max_count, method=method,
                              candidate_cap=candidate_cap, use_kernel=self.use_kernel)
        counts = self.match_counts(queries)
        if method == TopKMethod.CPQ:
            hist = None
            if self.use_kernel:
                from repro.kernels import ops as kops

                hist = kops.cpq_hist(counts, self.max_count)
            return _cpq.cpq_select(counts, params, hist=hist)
        if method == TopKMethod.SPQ:
            return _spq.spq_select(counts, params)
        return _cpq.sort_select(counts, params)

    def search_multiload(self, queries, k: int, n_parts: int) -> TopKResult:
        """Paper section III-D: split this index into parts and stream them."""
        n = self.stats.n_objects
        part = -(-n // n_parts)
        pad = part * n_parts - n
        data = self.data
        if pad:
            fill = jnp.full((pad,) + data.shape[1:], -1, dtype=data.dtype)
            data = jnp.concatenate([data, fill], axis=0)
        chunks = data.reshape(n_parts, part, *data.shape[1:])
        params = SearchParams(k=k, max_count=self.max_count)
        if self.engine == Engine.EQ:
            match_fn = lambda d, q: _match.match_eq(d, q)
        elif self.engine == Engine.MINSUM:
            match_fn = lambda d, q: _match.match_minsum(d, q)
        else:
            raise ValueError("multiload demo supports EQ/MINSUM engines")
        return _multiload.multiload_search(chunks, jnp.asarray(queries), params, match_fn)
