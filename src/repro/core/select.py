"""Unified top-k selection: one pipeline for every search path.

`select_topk` dispatches on `SearchParams.method` (c-PQ gate / SPQ bucket
narrowing / full sort) and optionally consumes the fused Pallas histogram
(kernels/cpq_hist) so the Gate reconstruction never re-reads the counts
matrix on the kernel path.

Its only caller is the unified executor (core/plan.py) -- monolithic,
segmented, multiload, and distributed layouts all select through the same
per-part step there, which is what makes the selection strategy a
*parameter* of a search rather than a property of the call site: every
layout honours `method` exactly like single-device search does.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cpq as _cpq
from repro.core import spq as _spq
from repro.core.types import SearchParams, TopKMethod, TopKResult


def select_topk(
    counts: jnp.ndarray,
    params: SearchParams,
    hist: jnp.ndarray | None = None,
    use_fused_hist: bool = False,
) -> TopKResult:
    """Exact top-k by match count.  counts: int [Q, N] -> TopKResult [Q, k].

    hist:           precomputed count histogram [Q, max_count + 1] (optional).
    use_fused_hist: compute the histogram with the Pallas kernel when `hist`
                    is not supplied (single-device kernel path; scan/shard_map
                    callers default to the jnp reference histogram).
    """
    if params.method == TopKMethod.CPQ:
        if hist is None and use_fused_hist:
            from repro.kernels import ops as kops

            hist = kops.cpq_hist(counts, params.max_count)
        return _cpq.cpq_select(counts, params, hist=hist)
    if params.method == TopKMethod.SPQ:
        return _spq.spq_select(counts, params)
    if params.method == TopKMethod.SORT:
        return _cpq.sort_select(counts, params)
    raise ValueError(f"unknown top-k method {params.method}")
