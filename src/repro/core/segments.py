"""SegmentedIndex: incremental append + compaction over immutable segments.

`RetrievalService.add` used to rebuild its GenieIndex from scratch on every
call, so filling a corpus of N items in B batches cost O(N^2/B) device work
and re-uploaded all signatures each time.  This module fixes that bug the way
FAISS shards billion-scale GPU indexes (Johnson et al. 1702.08734): each
`add()` seals the batch into an immutable per-segment `GenieIndex` (O(batch)
device work), `search()` builds a SEGMENTED QueryPlan over the sealed parts
and delegates to the unified executor (core/plan.py) which matches, selects,
and merges the cap-sized candidate buffers exactly, and
`compact(max_segments)` coalesces adjacent segments so steady-state search
cost stays flat as the corpus grows.

The merge is exact, not approximate: segments *partition* the object set, so
an object's match count is computed entirely inside its own segment (the same
invariant multiload streaming and the distributed shard merge already rely
on).  Any global top-k member is a top-min(k, n_seg) member of its segment,
hence per-segment buffers of width min(k, n_seg) always contain the global
top-k, and the merged ordering (count desc, global id asc) is identical to a
monolithic search -- ids and counts match exactly for every registered
engine (tests/test_segments.py).

Compaction only ever merges *adjacent* segments: global ids are assigned by
cumulative segment offset in append order, and concatenating neighbours
preserves that order, so compaction never remaps an id.

    seg = SegmentedIndex(Engine.EQ)
    seg.add(sigs_batch_0)              # seals segment 0
    seg.add(sigs_batch_1)              # seals segment 1 -- no rebuild
    res = seg.search(queries, k=10)    # == monolithic GenieIndex search
    seg.compact(max_segments=1)        # coalesce; ids unchanged
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import engines as _engines
from repro.core import plan as _plan
from repro.core import routing as _routing
from repro.core.index import GenieIndex
from repro.core.types import (Engine, IndexStats, SignatureLayout,
                              TopKMethod, TopKResult)


def even_segments(n_objects: int, n_segments: int) -> list[int]:
    """Row counts of an even split of `n_objects` into `n_segments` parts."""
    if n_segments < 1:
        raise ValueError(f"n_segments must be >= 1, got {n_segments}")
    base, rem = divmod(n_objects, n_segments)
    return [base + (1 if i < rem else 0) for i in range(n_segments)]


def layout_accounting(segment_rows, row_bytes: int) -> dict:
    """Host-side accounting for a segmented layout (surfaced by launch/dryrun)."""
    rows = [int(r) for r in segment_rows]
    return dict(
        n_segments=len(rows),
        segment_rows=rows,
        total_rows=sum(rows),
        bytes_per_segment=[r * int(row_bytes) for r in rows],
        bytes_total=sum(rows) * int(row_bytes),
    )


@dataclasses.dataclass
class SegmentedIndex:
    """An append-only sequence of immutable per-batch GenieIndex segments.

    `max_count` may be left None: the first `add` resolves it through the
    engine's derived bound (engines without one -- MINSUM, IP -- require it
    up front, exactly like `GenieIndex.build`), and every later segment is
    pinned to the same bound so counts stay comparable across segments.
    """

    engine: Engine
    max_count: Optional[int] = None
    use_kernel: bool = True
    segments: list[GenieIndex] = dataclasses.field(default_factory=list)
    compaction_count: int = 0
    compaction_seconds: float = 0.0
    # storage format every segment is sealed into (core/packing.py)
    signature_layout: SignatureLayout = SignatureLayout.WIDE

    def __post_init__(self):
        self.signature_layout = self.model.require_layout(self.signature_layout)

    # ------------------------------------------------------------------
    @property
    def model(self) -> _engines.MatchModel:
        return _engines.get(self.engine)

    @property
    def n_objects(self) -> int:
        return sum(s.stats.n_objects for s in self.segments)

    def __len__(self) -> int:
        return self.n_objects

    @property
    def segment_rows(self) -> list[int]:
        return [s.stats.n_objects for s in self.segments]

    @property
    def stats(self) -> IndexStats:
        """Aggregate IndexStats with per-segment build/compaction accounting."""
        segs = self.segments
        return IndexStats(
            n_objects=self.n_objects,
            n_lists=segs[0].stats.n_lists if segs else 0,
            total_postings=sum(s.stats.total_postings for s in segs),
            max_list_len=max((s.stats.max_list_len for s in segs), default=0),
            bytes_device=sum(s.stats.bytes_device for s in segs),
            build_seconds=sum(s.stats.build_seconds for s in segs),
            signature_layout=self.signature_layout.value,
            bytes_signatures_wide=sum(s.stats.bytes_signatures_wide for s in segs),
            bytes_signatures_packed=sum(s.stats.bytes_signatures_packed for s in segs),
            n_segments=len(segs),
            segment_rows=self.segment_rows,
            segment_build_seconds=[s.stats.build_seconds for s in segs],
            compaction_count=self.compaction_count,
            compaction_seconds=self.compaction_seconds,
            extra={"engine": self.engine.value},
        )

    # ------------------------------------------------------------------
    # Append
    # ------------------------------------------------------------------
    def add(self, raw_data) -> GenieIndex:
        """Seal one batch into a new immutable segment: O(batch) device work,
        no re-hash or re-upload of earlier segments."""
        import numpy as np

        shape = np.shape(raw_data)
        if not shape or shape[0] == 0:
            # an empty segment would poison every later search (0-row match)
            raise ValueError(f"cannot add an empty batch (shape {shape})")
        seg = GenieIndex.build(self.engine, raw_data, max_count=self.max_count,
                               use_kernel=self.use_kernel,
                               signature_layout=self.signature_layout)
        if self.segments:
            want = self.segments[0].data.shape[1:]
            if seg.data.shape[1:] != want:
                raise ValueError(
                    f"segment width mismatch: existing segments hold "
                    f"{tuple(want)} rows, new batch holds {tuple(seg.data.shape[1:])}"
                )
        if self.max_count is None:
            self.max_count = seg.max_count
        self.segments.append(seg)
        return seg

    # ------------------------------------------------------------------
    # Coarse routing (core/routing.py)
    # ------------------------------------------------------------------
    def router(self) -> _routing.Router:
        """A Router over the sealed segments' summaries (built at seal time,
        merged through compaction).  Raises when any segment lacks one --
        e.g. a GenieIndex assembled by hand outside build()."""
        if not self.segments:
            raise ValueError("empty SegmentedIndex: add() first")
        missing = [i for i, s in enumerate(self.segments) if s.summary is None]
        if missing:
            raise ValueError(
                f"segments {missing} carry no routing summary (assembled "
                f"outside GenieIndex.build?); routing needs per-segment "
                f"summaries"
            )
        return _routing.Router(engine=self.engine,
                               summaries=[s.summary for s in self.segments])

    def _routed_execute(self, plan, queries, routing: _routing.Routing,
                        router: _routing.Router | None = None) -> TopKResult:
        # the router scores canonical WIDE queries; the executor gets them
        # packed when the segments are PACKED
        q_wide = self.model.prepare_queries(queries)
        q_exec = q_wide
        if self.signature_layout is SignatureLayout.PACKED:
            q_exec = self.model.pack_queries(q_wide)
        if routing is _routing.Routing.NONE:
            router = None
        elif router is None:
            router = self.router()
        return _plan.execute(plan, [s.data for s in self.segments], q_exec,
                             router=router, route_queries=q_wide)

    # ------------------------------------------------------------------
    # Search: per-segment match + select, exact cap-buffer merge
    # ------------------------------------------------------------------
    def _tune_width(self) -> int:
        """Physical stored width (words/bytes when PACKED) for cache lookup."""
        return int(self.segments[0].data.shape[1])

    def search(self, queries, k: int, method: TopKMethod = TopKMethod.CPQ,
               candidate_cap: int | None = None,
               routing: _routing.Routing | str = _routing.Routing.NONE,
               nprobe: int | None = None,
               router: _routing.Router | None = None,
               tile_overrides=None, autotune=None) -> TopKResult:
        """`router` lets a caller that caches the Router across searches
        (serve/retrieval.py keys it on the corpus fingerprint) skip the
        per-search rebuild; ignored when routing is NONE.

        `autotune` consults the measured-knob cache (core/autotune.py); when
        the tuned entry prefers the MULTILOAD host loop over the SEGMENTED
        merge for this shape, the search delegates there -- both layouts
        stream the same per-part arrays and merge bit-for-bit identically,
        so the switch is pure orchestration cost.
        """
        if not self.segments:
            raise ValueError("empty SegmentedIndex: add() first")
        routing = _routing.Routing(routing)
        if autotune is not None and autotune is not False:
            from repro.core import autotune as _autotune

            entry = _autotune.consult(
                autotune, engine=self.engine,
                signature_layout=self.signature_layout,
                n=self.n_objects, width=self._tune_width(),
            )
            if entry is not None and entry.layout == "multiload_host":
                return self.search_multiload(
                    queries, k, method=method, candidate_cap=candidate_cap,
                    routing=routing, nprobe=nprobe, router=router,
                    tile_overrides=tile_overrides, autotune=autotune,
                )
        plan = _plan.plan_search(
            self.engine, k, self.max_count, layout=_plan.Layout.SEGMENTED,
            part_rows=tuple(self.segment_rows), method=method,
            candidate_cap=candidate_cap, use_kernel=self.use_kernel,
            signature_layout=self.signature_layout,
            routing=routing, nprobe=nprobe,
            tile_overrides=tile_overrides, autotune=autotune,
            tune_width=self._tune_width(),
        )
        return self._routed_execute(plan, queries, routing, router=router)

    def search_multiload(self, queries, k: int,
                         method: TopKMethod = TopKMethod.CPQ,
                         candidate_cap: int | None = None,
                         routing: _routing.Routing | str = _routing.Routing.NONE,
                         nprobe: int | None = None,
                         router: _routing.Router | None = None,
                         tile_overrides=None, autotune=None) -> TopKResult:
        """Stream the segments through the device one at a time (paper
        section III-D's host loop) -- segments of heterogeneous sizes are the
        parts, so nothing is re-concatenated or re-padded."""
        if not self.segments:
            raise ValueError("empty SegmentedIndex: add() first")
        routing = _routing.Routing(routing)
        plan = _plan.plan_search(
            self.engine, k, self.max_count, layout=_plan.Layout.MULTILOAD,
            part_rows=tuple(self.segment_rows), n_objects=self.n_objects,
            method=method, candidate_cap=candidate_cap,
            use_kernel=self.use_kernel, host_loop=True,
            signature_layout=self.signature_layout,
            routing=routing, nprobe=nprobe,
            tile_overrides=tile_overrides, autotune=autotune,
            tune_width=self._tune_width(),
        )
        return self._routed_execute(plan, queries, routing, router=router)

    # ------------------------------------------------------------------
    # Compaction
    # ------------------------------------------------------------------
    def compact(self, max_segments: int = 1) -> None:
        """Coalesce adjacent segments (smallest combined pair first) until at
        most `max_segments` remain.  Global ids are preserved: neighbours
        concatenate in append order.  O(n) device copy, no re-hash."""
        if max_segments < 1:
            raise ValueError(f"max_segments must be >= 1, got {max_segments}")
        if len(self.segments) <= max_segments:
            return
        segs = list(self.segments)
        t_total = 0.0
        while len(segs) > max_segments:
            sizes = [s.stats.n_objects for s in segs]
            i = min(range(len(segs) - 1), key=lambda j: sizes[j] + sizes[j + 1])
            # perf_counter, not time(): a wall-clock (NTP) step must never
            # record a negative compaction duration
            t0 = time.perf_counter()
            a, b = segs[i].stats, segs[i + 1].stats
            arr = jnp.concatenate([segs[i].data, segs[i + 1].data], axis=0)
            jax.block_until_ready(arr)
            t_total += time.perf_counter() - t0
            # aggregate the sources' stats instead of recomputing on `arr`:
            # every field is additive (or a max), and a PACKED `arr` holds
            # words/bytes -- build_stats would misread its width as signature
            # slots.  The merged segment keeps its sources' *build* time; the
            # concat cost is compaction accounting, not build accounting.
            stats = IndexStats(
                n_objects=a.n_objects + b.n_objects,
                n_lists=a.n_lists,
                total_postings=a.total_postings + b.total_postings,
                max_list_len=max(a.max_list_len, b.max_list_len),
                bytes_device=a.bytes_device + b.bytes_device,
                build_seconds=a.build_seconds + b.build_seconds,
                signature_layout=self.signature_layout.value,
                bytes_signatures_wide=(a.bytes_signatures_wide
                                       + b.bytes_signatures_wide),
                bytes_signatures_packed=(a.bytes_signatures_packed
                                         + b.bytes_signatures_packed),
                extra={"engine": self.engine.value},
            )
            # routing summaries merge like the stats: bounds widen, sketches
            # OR, centroids row-weight -- no recompute on the (possibly
            # packed) concatenated array.  A hand-assembled summary-less
            # source poisons the merge to None (router() then explains why).
            summary = None
            if segs[i].summary is not None and segs[i + 1].summary is not None:
                summary = _routing.merge_summaries(segs[i].summary,
                                                   segs[i + 1].summary)
            segs[i:i + 2] = [GenieIndex(engine=self.engine, max_count=self.max_count,
                                        data=arr, stats=stats,
                                        use_kernel=self.use_kernel,
                                        signature_layout=self.signature_layout,
                                        summary=summary)]
        self.segments = segs
        self.compaction_count += 1
        self.compaction_seconds += t_total

    # ------------------------------------------------------------------
    # Export for the distributed (sharded) layout
    # ------------------------------------------------------------------
    def concat_data(self, pad_multiple: int = 1) -> tuple[jnp.ndarray, int]:
        """(data, n_objects) for the distributed shard layout: segments
        concatenated in global-id order, row count padded up to a multiple of
        `pad_multiple` with the engine's pad fill.  Pass `n_objects` to
        `distributed.make_search_step` so pad rows are masked out of every
        shard's candidate buffer."""
        if not self.segments:
            raise ValueError("empty SegmentedIndex: add() first")
        data = jnp.concatenate([s.data for s in self.segments], axis=0)
        return _plan.pad_to_multiple(
            data, pad_multiple, self.model.pad_value_for(self.signature_layout))
