# The paper's primary contribution: GENIE generic inverted-index similarity
# search (match-count model, c-PQ selection, LSH/SA transforms, distributed
# merge).  See DESIGN.md for the GPU->TPU adaptation map.
from repro.core import cpq, distributed, index, match, merge, multiload, postings, spq  # noqa: F401
from repro.core.index import GenieIndex  # noqa: F401
from repro.core.types import Engine, SearchParams, TopKMethod, TopKResult  # noqa: F401
