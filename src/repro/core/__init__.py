# The paper's primary contribution: GENIE generic inverted-index similarity
# search (match-count model, c-PQ selection, LSH/SA transforms, distributed
# merge).  Engine dispatch lives in the MatchModel registry (core/engines.py);
# top-k selection is the shared select_topk pipeline (core/select.py).
from repro.core import (  # noqa: F401
    cpq, distributed, engines, index, match, merge, multiload, postings, segments,
    select, spq,
)
from repro.core.engines import MatchModel  # noqa: F401
from repro.core.index import GenieIndex  # noqa: F401
from repro.core.segments import SegmentedIndex  # noqa: F401
from repro.core.select import select_topk  # noqa: F401
from repro.core.types import Engine, SearchParams, TopKMethod, TopKResult  # noqa: F401
