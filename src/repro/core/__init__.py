# The paper's primary contribution: GENIE generic inverted-index similarity
# search (match-count model, c-PQ selection, LSH/SA transforms, distributed
# merge).  Engine dispatch lives in the MatchModel registry (core/engines.py);
# query execution is the unified plan->execute pipeline (core/plan.py): every
# search path builds a QueryPlan and delegates to the one executor that calls
# match kernels, pad masks, select_topk, and the merge buffers.
from repro.core import (  # noqa: F401
    cpq, distributed, engines, index, match, merge, multiload, plan, postings,
    routing, segments, select, spq,
)
from repro.core.engines import MatchModel  # noqa: F401
from repro.core.index import GenieIndex  # noqa: F401
from repro.core.plan import Layout, QueryPlan, execute, plan_search  # noqa: F401
from repro.core.routing import Router, Routing, SegmentSummary  # noqa: F401
from repro.core.segments import SegmentedIndex  # noqa: F401
from repro.core.select import select_topk  # noqa: F401
from repro.core.types import Engine, SearchParams, TopKMethod, TopKResult  # noqa: F401
