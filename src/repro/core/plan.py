"""Unified query planning + execution: one plan -> execute pipeline for every
GENIE search path.

The execution layer had quietly forked into four near-copies of the same
loop -- `GenieIndex.search`, `SegmentedIndex.search`/`search_multiload`,
`multiload_search(_host)`, and the distributed shard_map step each re-derived
engine dispatch, pad masking, per-part k-clamping, and top-k merging.  This
module is the consolidation (the Faiss plan/execute split of Johnson et al.
1702.08734, FLASH's host-orchestrated part streaming for memory-bound
corpora):

  * `plan_search(...)` is the single entry point that describes a search as a
    `QueryPlan`: the engine, the part layout (monolithic / segments /
    multiload parts / mesh shards), the pad policy, the per-part k clamp, and
    the merge strategy.
  * `execute(plan, data, queries)` is the ONLY code in the system that calls
    match kernels, pad masking, `select_topk`, and the `core/merge` buffers.
    Every legacy entry point is now a thin adapter that builds a plan and
    delegates here.
  * Compiled executables are cached per plan (`_EXEC_CACHE`): repeated
    queries with the same (engine, layout shape, k, method, use_kernel)
    reuse the jitted program instead of re-tracing.  `trace_count(plan)`
    exposes the per-plan trace counter so tests (and the serve-latency
    benchmark) can assert cache hits.

The four layouts and their merge strategies:

  MONOLITHIC   one device-resident part; selection IS the merge.
  SEGMENTED    host loop over immutable per-segment parts (heterogeneous
               rows); per-part buffers of width min(k, rows) merged exactly
               by `merge_ragged` (parts partition the object set).
  MULTILOAD    paper section III-D part streaming: either a stacked
               [C, Nc, ...] lax.scan with an incremental pairwise merge
               (device-resident stack) or the literal host loop
               (`host_loop=True`, parts swapped through the device).
  DISTRIBUTED  mesh shards under shard_map; per-shard buffers all-gathered
               and merged collectively (optionally hierarchically: pod-local
               first, then across pods).

Invariants owned here (and deleted from the four former copies):
pad-never-in-topk (counts of rows with global id >= n_objects are forced to
-1 *before* selection), the (count desc, id asc) tie-break (stable buffer
merges over id-ascending parts), and the ragged per-part k clamp.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import cpq as _cpq
from repro.core import engines as _engines
from repro.core import merge as _merge
from repro.core import routing as _routing
from repro.core.routing import Routing
from repro.core.select import select_topk
from repro.core.types import (Engine, SearchParams, SignatureLayout,
                              TopKMethod, TopKResult)

# jax >= 0.6 promotes shard_map to the top level (keyword `check_vma`);
# earlier releases keep it in jax.experimental (keyword `check_rep`).
try:
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

MatchLike = Union[Engine, str, "_engines.MatchModel",
                  Callable[[jnp.ndarray, Any], jnp.ndarray]]


class Layout(str, enum.Enum):
    """Part layout of a planned search (the taxonomy in docs/EXECUTION.md)."""

    MONOLITHIC = "monolithic"      # one device-resident data matrix
    SEGMENTED = "segmented"        # host loop over sealed per-batch segments
    MULTILOAD = "multiload"        # streamed index parts (scan or host loop)
    DISTRIBUTED = "distributed"    # object shards across a device mesh


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A fully-resolved description of one search: who matches, over which
    parts, how pads are masked, how much each part contributes to the merge.

    Hashable by construction -- the plan IS the executable-cache key.
    """

    match: Callable[[jnp.ndarray, Any], jnp.ndarray]  # canonical match fn
    params: SearchParams
    layout: Layout
    part_rows: tuple[int, ...] = ()    # physical rows per part ((): deferred)
    n_objects: Optional[int] = None    # real corpus rows; None = nothing padded
    engine: Optional[Engine] = None    # None when `match` is a raw callable
    pad_value: Any = None              # engine fill for padded rows
    fused_hist: bool = False           # single-device fused Pallas histogram
    host_loop: bool = False            # MULTILOAD: host streaming vs lax.scan
    hierarchical: bool = False         # DISTRIBUTED: pod-local merge first
    mesh_axes: tuple[str, ...] = ()    # DISTRIBUTED: mesh axis names
    # signature storage format the match fn expects (core/packing.py); part
    # of the plan hash, so WIDE and PACKED executables never collide in cache
    signature_layout: SignatureLayout = SignatureLayout.WIDE
    # fused match->count->local-top-k kernel fn(data, queries, k) ->
    # (ids, counts) candidate buffers; None => count matrix + select_topk
    fused_match: Optional[Callable[[jnp.ndarray, Any, int], tuple]] = None
    # coarse routing mode (core/routing.py): NONE scans every part; ROUTED /
    # ROUTED_VERIFIED prune via a Router built from segment summaries.  Part
    # of the plan hash, so routed and full-scan executables never collide.
    routing: Routing = Routing.NONE
    # probe width for ROUTED/ROUTED_VERIFIED; None = Router's sqrt(S) default
    nprobe: Optional[int] = None
    # tuned kernel tile sizes as canonical sorted ((knob, value), ...) pairs
    # (core/autotune.py; engines.canonical_tile_overrides).  Part of the plan
    # hash: tuned and default executables never collide in cache, and the
    # memoized tile-bound match callables keep equal plans key-equal.
    tile_overrides: tuple = ()

    # -- derived layout facts ----------------------------------------------
    @property
    def n_parts(self) -> int:
        return len(self.part_rows)

    @property
    def total_rows(self) -> int:
        return sum(self.part_rows)

    @property
    def pad_rows(self) -> int:
        if self.n_objects is None or not self.part_rows:
            return 0
        return self.total_rows - self.n_objects

    def part_k(self, rows: int) -> int:
        """Ragged k clamp: a part smaller than k contributes only
        min(k, rows) candidates (host-loop layouts)."""
        return min(self.params.k, rows)

    def merge_strategy(self) -> str:
        if self.layout == Layout.MONOLITHIC:
            return "none"
        if self.layout == Layout.DISTRIBUTED:
            return "collective-hierarchical" if self.hierarchical else "collective"
        if self.layout == Layout.MULTILOAD and not self.host_loop:
            return "incremental-pairwise"
        return "ragged-buffer"

    def describe(self) -> dict:
        """Host-side plan summary (surfaced by launch/dryrun cost reports)."""
        rows = list(self.part_rows)
        # both per-part lists truncate identically: a "..." marker past 32
        # parts, never a silent cut (the lists must stay row-aligned)
        truncated = len(rows) > 32
        part_k = [self.part_k(r) for r in rows[:32]]
        return dict(
            layout=self.layout.value,
            engine=self.engine.value if self.engine else "<callable>",
            k=self.params.k,
            method=self.params.method.value,
            use_kernel=self.params.use_kernel,
            n_parts=self.n_parts,
            part_rows=rows[:32] + ["..."] if truncated else rows,
            part_k=part_k + ["..."] if truncated else part_k,
            n_objects=self.n_objects,
            pad_rows=self.pad_rows,
            merge=self.merge_strategy(),
            host_loop=self.host_loop,
            hierarchical=self.hierarchical,
            mesh_axes=list(self.mesh_axes),
            fused_hist=self.fused_hist,
            signature_layout=self.signature_layout.value,
            fused_match=self.fused_match is not None,
            routing=self.routing.value,
            nprobe=self.nprobe,
            tile_overrides=dict(self.tile_overrides),
        )


def plan_search(
    engine: MatchLike,
    k: int,
    max_count: int,
    *,
    layout: Layout = Layout.MONOLITHIC,
    part_rows: Optional[Sequence[int]] = None,
    n_parts: Optional[int] = None,
    n_objects: Optional[int] = None,
    method: TopKMethod = TopKMethod.CPQ,
    candidate_cap: Optional[int] = None,
    use_kernel: bool = True,
    host_loop: bool = False,
    hierarchical: bool = False,
    mesh_axes: Sequence[str] = (),
    signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
    routing: Routing | str = Routing.NONE,
    nprobe: Optional[int] = None,
    tile_overrides: Optional[Any] = None,
    autotune: Optional[Any] = None,
    tune_width: Optional[int] = None,
) -> QueryPlan:
    """The single planning entry point: resolve the engine, lay out the
    parts, fix the pad policy and merge strategy, return the QueryPlan.

    `engine` may be an Engine, its string value, a MatchModel, or a raw
    canonical callable ``fn(data, queries) -> counts`` (back-compat with code
    that hands bare match functions to multiload/distributed search).

    Layout shape: pass `part_rows` (explicit, possibly ragged part sizes) or
    `n_parts` with `n_objects` (an even split padded up to divisibility --
    the classic multiload partition).  DISTRIBUTED plans defer the shape to
    compile time (shard_map splits whatever data arrives).

    `signature_layout` selects the storage format the data/queries arrive in
    (core/packing.py): PACKED plans dispatch the packed match fns and -- on
    the single-device kernel paths with nothing padded -- the fused
    match->count->local-top-k kernel, so the [Q, N] count matrix never
    leaves VMEM.  Engines without a packed format reject PACKED here.

    `routing` plans coarse segment/shard pruning (core/routing.py): ROUTED
    and ROUTED_VERIFIED plans execute against a Router built from segment
    summaries (`execute(..., router=...)`) and skip the parts/shards the
    router rules out.  Routing prunes host-streamed parts or mesh shards, so
    it requires a part-structured layout: SEGMENTED, MULTILOAD with
    host_loop=True, or DISTRIBUTED -- the single-program scans (MONOLITHIC,
    scanned MULTILOAD) have nothing to skip and reject it here.

    `tile_overrides` binds kernel tile sizes (tile_q/tile_n/tile_v/tile_m --
    the knobs kernels/ops.py accepts) onto the kernel dispatch path; it is
    rejected for use_kernel=False plans and raw callables.  `autotune`
    consults a measured-knob cache (core/autotune.py: True for the default
    cache, a path, or an AutotuneCache) and fills tile_overrides /
    candidate_cap / nprobe / fused-match preference for whatever the caller
    left unset -- explicit arguments always win, and a cache miss (including
    a hardware-fingerprint mismatch) silently keeps the defaults.
    `tune_width` is the physical signature width hint for cache bucketing.
    """
    sig_layout = SignatureLayout(signature_layout)
    model: Optional[_engines.MatchModel] = None
    match: Any = None
    if callable(engine) and not isinstance(engine, (_engines.MatchModel, Engine, str)):
        # raw callables own the layout contract; the plan just records it
        match = engine
    else:
        model = _engines.get(engine)
        sig_layout = model.require_layout(sig_layout)

    tiles = _engines.canonical_tile_overrides(tile_overrides)
    tuned_fused: Optional[bool] = None
    if autotune is not None and autotune is not False and model is not None:
        # lazy import: the autotuner times candidate plans through this very
        # module, so a top-level import would be circular
        from repro.core import autotune as _autotune

        n_hint = n_objects
        if n_hint is None and part_rows is not None:
            n_hint = sum(int(r) for r in part_rows)
        entry = _autotune.consult(
            autotune, engine=model.engine, signature_layout=sig_layout,
            n=n_hint, width=tune_width,
        )
        if entry is not None:
            # tuned knobs fill only what the caller left unset: explicit
            # arguments always win over the cache.  Tile sizes and the fused
            # preference are kernel-path knobs; candidate_cap and nprobe
            # shape selection on every dispatch path (incl. use_kernel=False
            # plans like the dry-run's lowered XLA fallback).
            if use_kernel:
                if not tiles and entry.tile_overrides:
                    tiles = _engines.canonical_tile_overrides(
                        entry.tile_overrides)
                tuned_fused = entry.fused_match
            if candidate_cap is None and entry.candidate_cap is not None:
                candidate_cap = int(entry.candidate_cap)
            if (nprobe is None and entry.nprobe is not None
                    and Routing(routing) is not Routing.NONE):
                nprobe = int(entry.nprobe)
    if tiles:
        if model is None:
            raise ValueError(
                "tile_overrides require a registered engine; a raw match "
                "callable owns its own tiling"
            )
        if not use_kernel:
            raise ValueError(
                "tile_overrides only apply to kernel dispatch; "
                "use_kernel=False plans take none"
            )
    if model is not None:
        match = model.match_fn(use_kernel, sig_layout, tiles)

    layout = Layout(layout)
    if part_rows is None and n_parts is not None:
        if n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {n_parts}")
        if n_objects is None:
            raise ValueError("an even multiload split needs n_objects")
        per = -(-n_objects // n_parts)
        part_rows = (per,) * n_parts
    rows = tuple(int(r) for r in part_rows) if part_rows is not None else ()
    if layout in (Layout.SEGMENTED, Layout.MULTILOAD) and not rows:
        raise ValueError(f"{layout.value} layout requires part_rows (or n_parts)")
    if layout == Layout.MONOLITHIC and len(rows) > 1:
        raise ValueError(f"monolithic layout got {len(rows)} parts")
    if any(r < 1 for r in rows):
        raise ValueError(f"part_rows must be positive, got {rows}")
    if layout == Layout.MULTILOAD and not host_loop and len(set(rows)) > 1:
        # the scanned executor derives global-id offsets as i * part_rows[0];
        # ragged parts would silently globalise wrong ids
        raise ValueError(
            f"scanned multiload layout requires uniform part_rows, got {rows}; "
            f"pass host_loop=True to stream ragged parts"
        )

    routing = Routing(routing)
    host_looped = bool(host_loop) and layout == Layout.MULTILOAD
    if routing is not Routing.NONE:
        routable = (layout == Layout.SEGMENTED or host_looped
                    or layout == Layout.DISTRIBUTED)
        if not routable:
            raise ValueError(
                f"routing={routing.value!r} prunes host-streamed parts or "
                f"mesh shards; a {layout.value} plan"
                f"{'' if host_loop or layout != Layout.MULTILOAD else ' (scanned)'}"
                f" is one device program with nothing to skip -- use "
                f"routing='none', or a SEGMENTED / MULTILOAD host_loop / "
                f"DISTRIBUTED layout"
            )
        if nprobe is not None and int(nprobe) < 1:
            raise ValueError(f"nprobe must be >= 1, got {nprobe}")
        nprobe = None if nprobe is None else int(nprobe)
    else:
        nprobe = None  # keep full-scan plans' cache keys canonical

    params = SearchParams(k=k, max_count=max_count, method=method,
                          candidate_cap=candidate_cap, use_kernel=use_kernel)
    # The fused Pallas histogram runs on the single-device paths only; the
    # scan / shard_map paths keep the jnp reference histogram (unchanged
    # behaviour of the four pre-planner copies).
    fused = use_kernel and layout in (Layout.MONOLITHIC, Layout.SEGMENTED)
    # The fused match->count->local-top-k kernel replaces the whole
    # count+select pipeline.  Same single-device gating as fused_hist, plus
    # n_objects None: the kernel masks pad columns by *physical* row id, so
    # engine-filled pad rows (multiload stacks, mesh divisibility) must not
    # be present -- those layouts keep the packed count kernel + the
    # structural _mask_pad_counts instead.
    fused_topk = None
    if (model is not None and sig_layout is SignatureLayout.PACKED
            and use_kernel and n_objects is None
            and layout in (Layout.MONOLITHIC, Layout.SEGMENTED)
            and tuned_fused is not False):
        fused_topk = model.fused_topk_fn(tiles)
    return QueryPlan(
        match=match, params=params, layout=layout, part_rows=rows,
        n_objects=n_objects, engine=model.engine if model else None,
        pad_value=model.pad_value_for(sig_layout) if model else None,
        fused_hist=fused,
        host_loop=host_looped,
        hierarchical=bool(hierarchical), mesh_axes=tuple(mesh_axes),
        signature_layout=sig_layout, fused_match=fused_topk,
        routing=routing, nprobe=nprobe, tile_overrides=tiles,
    )


# ---------------------------------------------------------------------------
# Batch compatibility (the serving front-end's coalescing key)
# ---------------------------------------------------------------------------

def k_bucket(k: int) -> int:
    """Round k up to the next power of two (floor 1).

    The serving front-end (serve/frontend.py) coalesces concurrent requests
    into one device dispatch; bucketing k means requests for k=5 and k=8
    share the k=8 executable instead of fragmenting the plan cache per exact
    k.  Truncating a top-8 result to a request's own k is bit-for-bit
    identical to searching at that k: the (count desc, id asc) order is
    total, so a top-k result is a prefix of any larger top-k' result."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    return 1 << (int(k) - 1).bit_length()


def batch_compat_key(
    engine: Engine | str,
    layout: Layout | str,
    signature_layout: SignatureLayout | str,
    routing: Routing | str,
    method: TopKMethod | str,
    k: int,
    *,
    nprobe: Optional[int] = None,
    candidate_cap: Optional[int] = None,
) -> tuple:
    """The coalescing key of one serving request: two requests with equal
    keys can share a single planned dispatch (stacked queries, one
    executable) and still scatter bit-for-bit per-request results.

    The axes are exactly the ones the executable cache keys on -- engine x
    layout x signature_layout x routing x method x k-bucket -- plus the two
    knobs that change a plan's selection behaviour (nprobe, candidate_cap).
    An explicit candidate_cap disables k-bucketing: the effective buffer
    capacity is max(cap, k), so bucketing k would silently change the cap
    the caller pinned."""
    kb = int(k) if candidate_cap is not None else k_bucket(k)
    return (
        Engine(engine) if not isinstance(engine, Engine) else engine,
        Layout(layout),
        SignatureLayout(signature_layout),
        Routing(routing),
        TopKMethod(method),
        kb,
        nprobe,
        candidate_cap,
    )


# ---------------------------------------------------------------------------
# Pad policy (the only pad masking / pad filling in the system)
# ---------------------------------------------------------------------------

def _mask_pad_counts(counts: jnp.ndarray, offset, n_objects: Optional[int]) -> jnp.ndarray:
    """Force pad columns (global id >= n_objects) to count -1 *before*
    selection, so pad rows can never crowd real candidates out of a candidate
    buffer.  This makes pad safety structural for every engine: the
    `pad_value` fill only has to be representable, not score-neutral
    (COSINE's zero rows, for instance, score V/2 against any query)."""
    if n_objects is None:
        return counts
    gcol = offset + jnp.arange(counts.shape[-1], dtype=jnp.int32)
    return jnp.where((gcol < n_objects)[None, :], counts, -1)


def _mask_invalid(gids: jnp.ndarray, counts: jnp.ndarray, n_objects: Optional[int]):
    """Drop padding rows post-selection: ids at/above the true object count
    never merge (belt to `_mask_pad_counts`'s braces)."""
    valid = gids >= 0
    if n_objects is not None:
        valid &= gids < n_objects
    return jnp.where(valid, gids, -1), jnp.where(valid, counts, -1)


def pad_to_multiple(data: jnp.ndarray, multiple: int, pad_value) -> tuple[jnp.ndarray, int]:
    """(padded data, true row count): append engine-fill rows up to the next
    multiple (mesh divisibility, even part splits)."""
    n = int(data.shape[0])
    pad = (-n) % max(int(multiple), 1)
    if pad:
        fill = jnp.full((pad,) + data.shape[1:], pad_value, dtype=data.dtype)
        data = jnp.concatenate([data, fill], axis=0)
    return data, n


def pad_and_stack(plan: QueryPlan, data: jnp.ndarray) -> jnp.ndarray:
    """Materialise a MULTILOAD scan layout from a monolithic data matrix:
    pad with the plan's engine fill and stack into [C, Nc, ...] chunks."""
    if plan.layout != Layout.MULTILOAD or not plan.part_rows:
        raise ValueError(f"pad_and_stack needs a MULTILOAD plan, got {plan.layout}")
    if plan.pad_value is None:
        raise ValueError("pad_and_stack needs an engine-resolved plan "
                         "(raw-callable plans carry no pad fill)")
    per = plan.part_rows[0]
    want = per * plan.n_parts
    n = int(data.shape[0])
    if n > want:
        raise ValueError(f"data has {n} rows but the plan lays out {want}")
    if n < want:
        fill = jnp.full((want - n,) + data.shape[1:], plan.pad_value,
                        dtype=data.dtype)
        data = jnp.concatenate([data, fill], axis=0)
    return data.reshape(plan.n_parts, per, *data.shape[1:])


# ---------------------------------------------------------------------------
# The executable cache + per-plan trace counter
# ---------------------------------------------------------------------------

_EXEC_CACHE: dict = {}
_TRACE_COUNTS: dict = {}
# FIFO bound on retained executables: jitted wrappers pin their compiled
# programs, so a long-lived serving process interleaving adds and searches
# must not accumulate stale entries forever.
PLAN_CACHE_CAP = 256


def _note_trace(key) -> None:
    # runs at trace time only (python body of a jitted function): counts how
    # often an executable was actually re-traced vs served from cache
    _TRACE_COUNTS[key] = _TRACE_COUNTS.get(key, 0) + 1


def _is_host_loop(plan: QueryPlan) -> bool:
    return plan.layout == Layout.SEGMENTED or (
        plan.layout == Layout.MULTILOAD and plan.host_loop)


def trace_count(plan: QueryPlan) -> int:
    """How many times this plan's executables have been traced (a cache hit
    leaves the counter unchanged).  Host-loop plans sum their per-part
    kernels (parts with equal row counts share one); distributed plans sum
    across meshes."""
    if _is_host_loop(plan):
        return sum(_TRACE_COUNTS.get(k, 0)
                   for k in {_part_key(plan, r) for r in plan.part_rows})
    if plan.layout == Layout.DISTRIBUTED:
        return sum(v for k, v in _TRACE_COUNTS.items()
                   if k[0] == "dist" and k[1] == plan)
    tag = "mono" if plan.layout == Layout.MONOLITHIC else "scan"
    return _TRACE_COUNTS.get((tag, plan), 0)


def plan_cache_size() -> int:
    return len(_EXEC_CACHE)


def clear_plan_cache() -> None:
    _EXEC_CACHE.clear()
    _TRACE_COUNTS.clear()


def _cached(key, builder):
    fn = _EXEC_CACHE.get(key)
    if fn is None:
        while len(_EXEC_CACHE) >= PLAN_CACHE_CAP:
            evicted = next(iter(_EXEC_CACHE))             # FIFO eviction
            _EXEC_CACHE.pop(evicted)
            _TRACE_COUNTS.pop(evicted, None)  # drop the counter twin too, or
            # the leak guard merely relocates the leak into the trace dict
        fn = _EXEC_CACHE[key] = builder()
    return fn


# ---------------------------------------------------------------------------
# Executors: the ONLY callers of match kernels, pad masks, select, and merge
# ---------------------------------------------------------------------------

def _part_topk(plan: QueryPlan, data: jnp.ndarray, queries: Any, offset,
               k: Optional[int] = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One part's candidate buffer: match -> pad mask -> select -> globalise.

    The shared core of every layout.  Returns (global ids, counts), both
    [Q, k], empty slots -1."""
    params = plan.params if k is None or k == plan.params.k \
        else dataclasses.replace(plan.params, k=k)
    counts = _mask_pad_counts(plan.match(data, queries), offset, plan.n_objects)
    local = select_topk(counts, params, use_fused_hist=plan.fused_hist)
    gids = jnp.where(local.ids >= 0, local.ids + offset, -1)
    return _mask_invalid(gids, local.counts, plan.n_objects)


def _fused_candidates_topk(fused_match, data, queries, k: int):
    """Run a fused match->count->local-top-k kernel and reduce its per-tile
    candidate buffers to the final (ids, counts) [Q, k].

    Per-tile buffers arrive in (count desc, id asc) order with tiles in
    ascending global-id ranges, so the buffer as a whole is id-ascending
    within equal counts -- exactly what topk_from_candidates' stable merge
    needs for the global tie-break."""
    cids, ccnt = fused_match(data, queries, k)
    if cids.shape[1] < k:  # tiny corpus: fewer candidate slots than k
        fill = jnp.full((cids.shape[0], k - cids.shape[1]), -1, jnp.int32)
        cids = jnp.concatenate([cids, fill], axis=1)
        ccnt = jnp.concatenate([ccnt, fill], axis=1)
    return _cpq.topk_from_candidates(cids, ccnt, k)


def _build_monolithic(plan: QueryPlan, key):
    if plan.fused_match is not None:
        k = plan.params.k

        def run_fused(data: jnp.ndarray, queries: Any) -> TopKResult:
            _note_trace(key)
            ids, counts = _fused_candidates_topk(plan.fused_match, data,
                                                 queries, k)
            return TopKResult(ids=ids, counts=counts, threshold=counts[:, -1])

        return jax.jit(run_fused)

    def run(data: jnp.ndarray, queries: Any) -> TopKResult:
        _note_trace(key)
        counts = _mask_pad_counts(plan.match(data, queries), 0, plan.n_objects)
        # selection is the merge: return select_topk's result (threshold
        # included) exactly as the pre-planner single-device search did
        return select_topk(counts, plan.params, use_fused_hist=plan.fused_hist)

    return jax.jit(run)


def _build_scan(plan: QueryPlan, key):
    nc = plan.part_rows[0]
    k = plan.params.k

    def run(chunks: jnp.ndarray, queries: Any) -> TopKResult:
        _note_trace(key)
        q = jax.tree_util.tree_leaves(queries)[0].shape[0]
        init = (jnp.full((q, k), -1, dtype=jnp.int32),
                jnp.full((q, k), -1, dtype=jnp.int32))

        def step(carry, xs):
            best_ids, best_counts = carry
            part, chunk_idx = xs
            gids, gcnt = _part_topk(plan, part, queries, chunk_idx * nc)
            ids = jnp.concatenate([best_ids, gids[:, :k]], axis=-1)
            cnt = jnp.concatenate([best_counts, gcnt[:, :k]], axis=-1)
            return _cpq.topk_from_candidates(ids, cnt, k), None

        xs = (chunks, jnp.arange(plan.n_parts, dtype=jnp.int32))
        (ids, counts), _ = jax.lax.scan(step, init, xs)
        return TopKResult(ids=ids, counts=counts, threshold=counts[:, -1])

    return jax.jit(run)


def _part_key(plan: QueryPlan, rows: int) -> tuple:
    """Cache key of a host-loop per-part kernel: only what the part program
    actually closes over -- NOT the whole plan, so growing the corpus (new
    part_rows / n_objects) keeps reusing kernels compiled for the same part
    shape (the id offset and pad boundary are traced scalars)."""
    params = dataclasses.replace(plan.params, k=plan.part_k(rows))
    return ("part", plan.match, params, plan.fused_hist, plan.fused_match,
            plan.n_objects is not None, rows)


def _part_fn(plan: QueryPlan, rows: int):
    """Cached per-part jitted kernel for the host-loop layouts: parts with
    the same row count share one compiled program across searches AND across
    corpus growth, so a 40-segment corpus of equal seals compiles once."""
    key = _part_key(plan, rows)
    match, fused = plan.match, plan.fused_hist
    fused_match = plan.fused_match
    params = dataclasses.replace(plan.params, k=plan.part_k(rows))
    masked = plan.n_objects is not None

    def build():
        def run(part, queries, offset, n_limit):
            _note_trace(key)
            if fused_match is not None:
                # fused plans are never masked (plan_search gates on
                # n_objects None): the kernel's own physical-row masking is
                # exhaustive, and parts arrive unpadded
                ids, cnts = _fused_candidates_topk(fused_match, part,
                                                   queries, params.k)
                return jnp.where(ids >= 0, ids + offset, -1), cnts
            counts = match(part, queries)
            if masked:
                counts = _mask_pad_counts(counts, offset, n_limit)
            local = select_topk(counts, params, use_fused_hist=fused)
            gids = jnp.where(local.ids >= 0, local.ids + offset, -1)
            if masked:
                return _mask_invalid(gids, local.counts, n_limit)
            return gids, local.counts

        return jax.jit(run)

    return _cached(key, build)


def _scan_host_parts(plan: QueryPlan, parts, queries,
                     part_mask: Optional[np.ndarray] = None) -> TopKResult:
    """One pass of the host loop over the (optionally masked) parts: each
    scanned part is swapped through the device, selected into a buffer of
    width min(k, rows), and the ragged buffers merge exactly.  Skipped parts
    never touch the device -- their rows' global ids simply advance the
    offset, so scanned parts keep their true id ranges."""
    n_limit = jnp.int32(plan.n_objects if plan.n_objects is not None else 0)
    buf_ids, buf_counts = [], []
    offset = 0
    for i, (part, rows) in enumerate(zip(parts, plan.part_rows)):
        if int(part.shape[0]) != rows:
            raise ValueError(f"part has {int(part.shape[0])} rows, plan says {rows}")
        if part_mask is None or part_mask[i]:
            part = jax.device_put(part)
            gids, gcnt = _part_fn(plan, rows)(part, queries, jnp.int32(offset),
                                              n_limit)
            buf_ids.append(gids)
            buf_counts.append(gcnt)
        offset += rows
    if not buf_ids:  # defensive: a router always selects >= 1 segment
        q = jax.tree_util.tree_leaves(queries)[0].shape[0]
        empty = jnp.full((q, plan.params.k), -1, dtype=jnp.int32)
        return TopKResult(ids=empty, counts=empty, threshold=empty[:, -1])
    return _merge.merge_ragged(buf_ids, buf_counts, plan.params.k)


def _route(plan: QueryPlan, router: Optional["_routing.Router"],
           queries, route_queries) -> tuple[np.ndarray, np.ndarray]:
    """Resolve the routed plan's (segment mask, upper bounds) on the host.

    `route_queries` are the canonical WIDE queries the summaries were built
    against; they default to the execution queries (correct whenever the
    plan's signature_layout is WIDE)."""
    if router is None:
        raise ValueError(
            f"a routing={plan.routing.value!r} plan needs router= (built "
            f"from segment summaries, e.g. SegmentedIndex.router())"
        )
    if _is_host_loop(plan) and tuple(router.part_rows) != plan.part_rows:
        raise ValueError(
            f"router summarises parts {tuple(router.part_rows)} but the plan "
            f"lays out {plan.part_rows}; rebuild the router from the current "
            f"segments"
        )
    rq = queries if route_queries is None else route_queries
    return router.select(rq, plan.nprobe)


def _skipped_could_contribute(result: TopKResult, ubs: np.ndarray,
                              verify_mask: np.ndarray) -> bool:
    """ROUTED_VERIFIED's fallback predicate: could any unscanned segment
    still place a member in the top-k?  True when a skipped segment's upper
    bound reaches the routed result's k-th count -- `>=`, not `>`, because a
    tied count with a smaller id displaces the k-th slot under the
    (count desc, id asc) order, and because an unfilled slot (threshold -1)
    must always force the fallback (every bound is >= a real count of 0)."""
    if not verify_mask.any():
        return False
    thresholds = np.asarray(result.threshold).astype(np.float64)  # [Q]
    return bool((ubs[:, verify_mask] >= thresholds[:, None]).any())


def _run_host_parts(plan: QueryPlan, parts, queries, router=None,
                    route_queries=None) -> TopKResult:
    """Host-orchestrated part streaming (SEGMENTED and MULTILOAD host_loop),
    with coarse routing when the plan asks for it: ROUTED scans only the
    router-selected parts; ROUTED_VERIFIED additionally checks the skipped
    parts' upper bounds against the routed threshold and falls back to the
    full scan when a skipped part could still contribute -- making it
    bit-for-bit identical to routing=NONE."""
    if len(parts) != plan.n_parts:
        raise ValueError(f"plan lays out {plan.n_parts} parts, got {len(parts)}")
    if plan.routing is Routing.NONE:
        return _scan_host_parts(plan, parts, queries)
    mask, ubs = _route(plan, router, queries, route_queries)
    routed = _scan_host_parts(plan, parts, queries, part_mask=mask)
    if plan.routing is Routing.ROUTED:
        return routed
    if not _skipped_could_contribute(routed, ubs, ~mask):
        return routed
    return _scan_host_parts(plan, parts, queries)


def _mesh_key(mesh: jax.sharding.Mesh) -> tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def _build_sharded(plan: QueryPlan, mesh: jax.sharding.Mesh, key):
    """The distributed executor: every shard runs the shared part kernel on
    its local object partition, then the cap-sized candidate buffers merge
    collectively (all-gather + small-buffer select; hierarchical plans merge
    pod-locally over cheap ICI first, then across pods over DCN).

    Routed plans take a third operand, `shard_active` int32 [n_shards]
    (replicated): inactive shards blank their candidate buffers to -1 before
    the gather, so unrouted shards contribute nothing to the merge.  Under
    SPMD every shard still runs the match (the savings routing buys on the
    host loops become result-masking here); an all-ones mask makes the
    program a bit-exact full scan, which is what the verified fallback
    re-runs -- same compiled executable, no second trace."""
    axes = tuple(mesh.axis_names)
    hier = plan.hierarchical and axes[0] == "pod"
    inner_axes = axes[1:] if hier else axes
    routed = plan.routing is not Routing.NONE

    def _local(data_local: jnp.ndarray, queries: Any,
               shard_active: Optional[jnp.ndarray] = None) -> TopKResult:
        _note_trace(key)
        n_local = data_local.shape[0]
        shard = _shard_linear_index(axes)
        gids, gcnt = _part_topk(plan, data_local, queries, shard * n_local)
        if shard_active is not None:
            on = shard_active[shard] > 0
            gids = jnp.where(on, gids, -1)
            gcnt = jnp.where(on, gcnt, -1)
        if not hier:
            all_ids = jax.lax.all_gather(gids, axis_name=axes, axis=0, tiled=False)
            all_cnt = jax.lax.all_gather(gcnt, axis_name=axes, axis=0, tiled=False)
            return _merge.merge_topk(all_ids, all_cnt, plan.params.k)
        # level 1: merge within the pod (over data/model axes)
        ids_in = jax.lax.all_gather(gids, axis_name=inner_axes, axis=0, tiled=False)
        cnt_in = jax.lax.all_gather(gcnt, axis_name=inner_axes, axis=0, tiled=False)
        pod = _merge.merge_topk(ids_in, cnt_in, plan.params.k)
        # level 2: merge across pods
        ids_out = jax.lax.all_gather(pod.ids, axis_name=("pod",), axis=0, tiled=False)
        cnt_out = jax.lax.all_gather(pod.counts, axis_name=("pod",), axis=0, tiled=False)
        return _merge.merge_topk(ids_out, cnt_out, plan.params.k)

    out_specs = TopKResult(ids=P(None, None), counts=P(None, None),
                           threshold=P(None))
    if routed:
        sharded = shard_map_compat(
            _local, mesh,
            in_specs=(P(axes), P(None, None), P(None)),
            out_specs=out_specs,
        )
    else:
        sharded = shard_map_compat(
            lambda data_local, queries: _local(data_local, queries), mesh,
            in_specs=(P(axes), P(None, None)),
            out_specs=out_specs,
        )
    return jax.jit(sharded)


def executable(plan: QueryPlan, mesh: Optional[jax.sharding.Mesh] = None):
    """The compiled-callable for a plan, from the cache when the same
    (engine, layout shape, k, method, use_kernel) was planned before.

    Returns ``fn(data, queries) -> TopKResult`` where `data`'s form follows
    the layout: one array (MONOLITHIC / DISTRIBUTED-sharded), a stacked
    [C, Nc, ...] array (MULTILOAD scan), or a list of per-part arrays
    (SEGMENTED / MULTILOAD host loop).  Routed DISTRIBUTED executables take
    a third operand, `shard_active` int32 [n_shards]; routed host-loop
    callables take `router=` / `route_queries=` keywords (both orchestrated
    by `execute`)."""
    if plan.layout == Layout.DISTRIBUTED:
        if mesh is None:
            raise ValueError("a DISTRIBUTED plan executes on a mesh; pass mesh=")
        key = ("dist", plan, _mesh_key(mesh))
        return _cached(key, lambda: _build_sharded(plan, mesh, key))
    if plan.layout == Layout.MONOLITHIC:
        key = ("mono", plan)
        return _cached(key, lambda: _build_monolithic(plan, key))
    if plan.layout == Layout.MULTILOAD and not plan.host_loop:
        key = ("scan", plan)
        return _cached(key, lambda: _build_scan(plan, key))
    # host-loop layouts: the python orchestration is free to rebuild; the
    # per-part compiled kernels underneath are the cached hot path
    return lambda parts, queries, router=None, route_queries=None: \
        _run_host_parts(plan, parts, queries, router=router,
                        route_queries=route_queries)


def _run_routed_sharded(plan: QueryPlan, data, queries,
                        mesh: jax.sharding.Mesh,
                        router: Optional["_routing.Router"],
                        route_queries) -> TopKResult:
    """Routed DISTRIBUTED execution: segments map onto the shards whose row
    ranges they overlap, unrouted shards blank their candidate buffers, and
    ROUTED_VERIFIED re-runs the same executable with an all-ones mask (a
    bit-exact full scan) when a segment with any inactive shard could still
    reach the routed threshold."""
    mask, ubs = _route(plan, router, queries, route_queries)
    n_total = int(data.shape[0])
    n_shards = int(np.prod(mesh.devices.shape))
    n_local = max(n_total // n_shards, 1)
    if sum(router.part_rows) > n_total:
        raise ValueError(
            f"router summarises {sum(router.part_rows)} rows but the sharded "
            f"data holds {n_total}; rebuild the router from the current "
            f"segments"
        )
    active = _routing.shard_mask(router.part_rows, mask, n_local, n_shards)
    fn = executable(plan, mesh=mesh)
    res = fn(data, queries, jnp.asarray(active, dtype=jnp.int32))
    if plan.routing is Routing.ROUTED:
        return res
    # a segment fully covered by active shards was scanned (possibly as a
    # bonus rider on a routed neighbour's shard) -- verify only the rest
    verify = _routing.segments_needing_verify(router.part_rows, active, n_local)
    if not _skipped_could_contribute(res, ubs, verify):
        return res
    return fn(data, queries, jnp.ones((n_shards,), dtype=jnp.int32))


def execute(plan: QueryPlan, data, queries,
            mesh: Optional[jax.sharding.Mesh] = None,
            router: Optional["_routing.Router"] = None,
            route_queries=None) -> TopKResult:
    """Run a planned search.  The only public door to the match/select/merge
    machinery -- every index/serving entry point delegates here.

    Routed plans (`plan.routing` != NONE) need `router=` -- a
    `routing.Router` over the current segments' summaries
    (`SegmentedIndex.router()`).  `route_queries=` supplies the canonical
    WIDE query pytree the summaries score against; it defaults to `queries`
    and must be passed whenever `queries` are PACKED (the router cannot read
    packed words)."""
    if plan.routing is not Routing.NONE and plan.layout == Layout.DISTRIBUTED:
        if mesh is None:
            raise ValueError("a DISTRIBUTED plan executes on a mesh; pass mesh=")
        return _run_routed_sharded(plan, data, queries, mesh, router,
                                   route_queries)
    if _is_host_loop(plan):
        return executable(plan, mesh=mesh)(data, queries, router=router,
                                           route_queries=route_queries)
    return executable(plan, mesh=mesh)(data, queries)


# ---------------------------------------------------------------------------
# Mesh helpers shared with core/distributed (which re-exports them)
# ---------------------------------------------------------------------------

def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})


def _axis_size(name: str) -> jnp.ndarray:
    # jax.lax.axis_size is newer-jax; psum(1) is its portable equivalent
    # (constant-folded at trace time).
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def _shard_linear_index(axes: tuple[str, ...]) -> jnp.ndarray:
    """Linearised shard index over the given mesh axes (row-major)."""
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx
