"""Distributed GENIE search over a (pod, data, model) TPU mesh.

Objects are partitioned across *every* mesh axis (a pure data-parallel object
shard -- the match-count of an object depends only on its own data row),
queries are replicated, each shard runs the dense match + top-k on its local
partition, and the per-shard Hash-Table buffers are merged with an
all-gather + small-buffer select.  This is the paper's multiple-loading merge
turned into a collective, and is the `search_step` lowered by the multi-pod
dry-run.

Both step builders are thin adapters over the unified planner (core/plan.py):
they describe the search as a DISTRIBUTED `QueryPlan` and return the planner's
compiled executable, so the shard_map body -- match dispatch, pad masking,
selection, collective merge -- lives in exactly one place and is cached per
(engine, layout, k, method, use_kernel) across repeated step constructions.

Engines are resolved through the MatchModel registry (core/engines.py): pass
an `Engine`, its string value, a `MatchModel`, or a raw canonical callable
``fn(data, queries) -> counts`` -- every registered engine (EQ, RANGE,
MINSUM, IP, TANIMOTO, COSINE) shards identically because the canonical
signature hides the query pytree shape (RANGE replicates its (lo, hi) pair).
`SearchParams.use_kernel` selects the per-shard match implementation, so the
Pallas kernels run *inside* shard_map on each shard's local partition --
kernel dispatch is no longer reference-only at pod scale.

Communication cost per query batch: S * Q * k * 8 bytes of (id, count) pairs
-- independent of N, the point of shipping candidate buffers instead of
counts.
"""
from __future__ import annotations

from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engines as _engines
from repro.core import plan as _plan
from repro.core.types import Engine, SearchParams, SignatureLayout, TopKResult

# Back-compat re-exports: the version-portable shard_map shims moved into the
# executor module with the shard_map body itself.
shard_map_compat = _plan.shard_map_compat
shard_linear_index = _plan._shard_linear_index

MatchLike = Union[Engine, str, "_engines.MatchModel",
                  Callable[[jnp.ndarray, Any], jnp.ndarray]]


def _plan_sharded(mesh: jax.sharding.Mesh, params: SearchParams,
                  match_fn: MatchLike, n_objects: int | None,
                  hierarchical: bool,
                  signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
                  ) -> _plan.QueryPlan:
    return _plan.plan_search(
        match_fn, params.k, params.max_count, layout=_plan.Layout.DISTRIBUTED,
        n_objects=n_objects, method=params.method,
        candidate_cap=params.candidate_cap, use_kernel=params.use_kernel,
        hierarchical=hierarchical, mesh_axes=tuple(mesh.axis_names),
        signature_layout=signature_layout,
    )


def make_search_step(
    mesh: jax.sharding.Mesh,
    params: SearchParams,
    match_fn: MatchLike,
    n_objects: int | None = None,
    signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
) -> Callable[[jnp.ndarray, Any], TopKResult]:
    """Build the jittable distributed search step.

    data:    [N, ...] (N divisible by the total mesh size; sharded dim 0).
    queries: canonical query pytree, replicated (each leaf [Q, ...]).
    Returns replicated TopKResult with global object ids.

    `params.use_kernel` picks the per-shard match path (Pallas kernel vs
    jnp reference) when `match_fn` resolves through the registry.

    `n_objects` enables the *segmented* shard layout: data is segments
    concatenated in global-id order and padded up to mesh divisibility
    (SegmentedIndex.concat_data), and rows with global id >= n_objects are
    pad fill -- their counts are forced to -1 before per-shard selection so
    they can never reach any candidate buffer.

    `signature_layout=PACKED` dispatches the packed per-shard match kernels:
    data and queries must arrive already packed (core/packing.py -- a PACKED
    SegmentedIndex's concat_data / prepare_queries_for produce them), so
    every shard moves the bit-packed bytes and the all-gathered candidate
    traffic is unchanged.
    """
    plan = _plan_sharded(mesh, params, match_fn, n_objects, hierarchical=False,
                         signature_layout=signature_layout)
    return _plan.executable(plan, mesh=mesh)


def make_hierarchical_search_step(
    mesh: jax.sharding.Mesh,
    params: SearchParams,
    match_fn: MatchLike,
    n_objects: int | None = None,
    signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
):
    """Two-level merge variant: reduce candidate buffers inside a pod first
    (cheap ICI), then across pods (expensive DCN) -- merge order does not
    change the result (merge is associative on partitioned objects), but the
    inter-pod traffic drops from S*Q*k to P_pods*Q*k pairs.

    Only meaningful on meshes with a leading "pod" axis; falls back to the
    flat merge otherwise.  `n_objects` masks segmented-layout pad rows,
    exactly as in `make_search_step`.
    """
    hier = tuple(mesh.axis_names)[0] == "pod"
    plan = _plan_sharded(mesh, params, match_fn, n_objects, hierarchical=hier,
                         signature_layout=signature_layout)
    return _plan.executable(plan, mesh=mesh)


def data_sharding(mesh: jax.sharding.Mesh) -> jax.sharding.NamedSharding:
    """NamedSharding for the object-partitioned data matrix [N, ...]."""
    return jax.sharding.NamedSharding(mesh, P(tuple(mesh.axis_names)))


def replicated(mesh: jax.sharding.Mesh, ndim: int) -> jax.sharding.NamedSharding:
    return jax.sharding.NamedSharding(mesh, P(*([None] * ndim)))
