"""Distributed GENIE search over a (pod, data, model) TPU mesh.

Objects are partitioned across *every* mesh axis (a pure data-parallel object
shard -- the match-count of an object depends only on its own signatures),
queries are replicated, each shard runs the dense match + c-PQ select on its
local partition, and the per-shard Hash-Table buffers are merged with an
all-gather + small-buffer select (core/merge.py).  This is the paper's
multiple-loading merge turned into a collective, and is the `search_step`
lowered by the multi-pod dry-run.

Communication cost per query batch: S * Q * k * 8 bytes of (id, count) pairs
-- independent of N, the point of shipping candidate buffers instead of
counts.
"""
from __future__ import annotations

import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax import shard_map

from repro.core import cpq as _cpq
from repro.core import merge as _merge
from repro.core.types import SearchParams, TopKResult


def shard_linear_index(axes: tuple[str, ...]) -> jnp.ndarray:
    """Linearised shard index over the given mesh axes (row-major)."""
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def make_search_step(
    mesh: jax.sharding.Mesh,
    params: SearchParams,
    match_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
) -> Callable[[jnp.ndarray, jnp.ndarray], TopKResult]:
    """Build the jittable distributed search step.

    data_sigs: [N, m] (N divisible by the total mesh size; sharded dim 0).
    query_sigs: [Q, m] replicated.
    Returns replicated TopKResult with global object ids.
    """
    axes = tuple(mesh.axis_names)
    n_shards = math.prod(mesh.devices.shape)

    def _local(data_local: jnp.ndarray, queries: jnp.ndarray) -> TopKResult:
        n_local = data_local.shape[0]
        counts = match_fn(data_local, queries)
        local = _cpq.cpq_select(counts, params)
        shard = shard_linear_index(axes)
        gids = jnp.where(local.ids >= 0, local.ids + shard * n_local, -1)
        # Gather every shard's candidate buffer: [S, Q, k].
        all_ids = jax.lax.all_gather(gids, axis_name=axes, axis=0, tiled=False)
        all_counts = jax.lax.all_gather(local.counts, axis_name=axes, axis=0, tiled=False)
        merged = _merge.merge_topk(all_ids, all_counts, params.k)
        return merged

    sharded = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(axes), P(None, None)),
        out_specs=TopKResult(ids=P(None, None), counts=P(None, None), threshold=P(None)),
        check_vma=False,
    )
    return jax.jit(sharded)


def data_sharding(mesh: jax.sharding.Mesh) -> jax.sharding.NamedSharding:
    """NamedSharding for the object-partitioned signature matrix [N, m]."""
    return jax.sharding.NamedSharding(mesh, P(tuple(mesh.axis_names)))


def replicated(mesh: jax.sharding.Mesh, ndim: int) -> jax.sharding.NamedSharding:
    return jax.sharding.NamedSharding(mesh, P(*([None] * ndim)))


def make_hierarchical_search_step(
    mesh: jax.sharding.Mesh,
    params: SearchParams,
    match_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
):
    """Two-level merge variant: reduce candidate buffers inside a pod first
    (cheap ICI), then across pods (expensive DCN) -- merge order does not
    change the result (merge is associative on partitioned objects), but the
    inter-pod traffic drops from S*Q*k to P_pods*Q*k pairs.

    Only meaningful on meshes with a leading "pod" axis; falls back to the
    flat merge otherwise.
    """
    axes = tuple(mesh.axis_names)
    if axes[0] != "pod":
        return make_search_step(mesh, params, match_fn)
    inner_axes = axes[1:]

    def _local(data_local: jnp.ndarray, queries: jnp.ndarray) -> TopKResult:
        n_local = data_local.shape[0]
        counts = match_fn(data_local, queries)
        local = _cpq.cpq_select(counts, params)
        shard = shard_linear_index(axes)
        gids = jnp.where(local.ids >= 0, local.ids + shard * n_local, -1)
        # level 1: merge within the pod (over data/model axes).
        ids_in = jax.lax.all_gather(gids, axis_name=inner_axes, axis=0, tiled=False)
        cnt_in = jax.lax.all_gather(local.counts, axis_name=inner_axes, axis=0, tiled=False)
        pod_merged = _merge.merge_topk(ids_in, cnt_in, params.k)
        # level 2: merge across pods.
        ids_out = jax.lax.all_gather(pod_merged.ids, axis_name=("pod",), axis=0, tiled=False)
        cnt_out = jax.lax.all_gather(pod_merged.counts, axis_name=("pod",), axis=0, tiled=False)
        return _merge.merge_topk(ids_out, cnt_out, params.k)

    sharded = shard_map(
        _local,
        mesh=mesh,
        in_specs=(P(axes), P(None, None)),
        out_specs=TopKResult(ids=P(None, None), counts=P(None, None), threshold=P(None)),
        check_vma=False,
    )
    return jax.jit(sharded)
