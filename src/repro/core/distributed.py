"""Distributed GENIE search over a (pod, data, model) TPU mesh.

Objects are partitioned across *every* mesh axis (a pure data-parallel object
shard -- the match-count of an object depends only on its own data row),
queries are replicated, each shard runs the dense match + shared `select_topk`
on its local partition, and the per-shard Hash-Table buffers are merged with
an all-gather + small-buffer select (core/merge.py).  This is the paper's
multiple-loading merge turned into a collective, and is the `search_step`
lowered by the multi-pod dry-run.

Engines are resolved through the MatchModel registry (core/engines.py): pass
an `Engine`, its string value, a `MatchModel`, or a raw canonical callable
``fn(data, queries) -> counts`` -- every registered engine (EQ, RANGE,
MINSUM, IP, TANIMOTO, COSINE) shards identically because the canonical
signature hides the query pytree shape (RANGE replicates its (lo, hi) pair).
`SearchParams.use_kernel` selects the per-shard match implementation, so the
Pallas kernels run *inside* shard_map on each shard's local partition --
kernel dispatch is no longer reference-only at pod scale.

Communication cost per query batch: S * Q * k * 8 bytes of (id, count) pairs
-- independent of N, the point of shipping candidate buffers instead of
counts.
"""
from __future__ import annotations

from typing import Any, Callable, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engines as _engines
from repro.core import merge as _merge
from repro.core.multiload import _mask_pad_counts
from repro.core.select import select_topk
from repro.core.types import Engine, SearchParams, TopKResult

# jax >= 0.6 promotes shard_map to the top level (keyword `check_vma`);
# earlier releases keep it in jax.experimental (keyword `check_rep`).
try:
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

MatchLike = Union[Engine, str, "_engines.MatchModel",
                  Callable[[jnp.ndarray, Any], jnp.ndarray]]


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})


def _axis_size(name: str) -> jnp.ndarray:
    # jax.lax.axis_size is newer-jax; psum(1) is its portable equivalent
    # (constant-folded at trace time).
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_linear_index(axes: tuple[str, ...]) -> jnp.ndarray:
    """Linearised shard index over the given mesh axes (row-major)."""
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def _out_specs() -> TopKResult:
    return TopKResult(ids=P(None, None), counts=P(None, None), threshold=P(None))


def make_search_step(
    mesh: jax.sharding.Mesh,
    params: SearchParams,
    match_fn: MatchLike,
    n_objects: int | None = None,
) -> Callable[[jnp.ndarray, Any], TopKResult]:
    """Build the jittable distributed search step.

    data:    [N, ...] (N divisible by the total mesh size; sharded dim 0).
    queries: canonical query pytree, replicated (each leaf [Q, ...]).
    Returns replicated TopKResult with global object ids.

    `params.use_kernel` picks the per-shard match path (Pallas kernel vs
    jnp reference) when `match_fn` resolves through the registry.

    `n_objects` enables the *segmented* shard layout: data is segments
    concatenated in global-id order and padded up to mesh divisibility
    (SegmentedIndex.concat_data), and rows with global id >= n_objects are
    pad fill -- their counts are forced to -1 before per-shard selection so
    they can never reach any candidate buffer.
    """
    axes = tuple(mesh.axis_names)
    match = _engines.resolve_match_fn(match_fn, params.use_kernel)

    def _local(data_local: jnp.ndarray, queries: Any) -> TopKResult:
        n_local = data_local.shape[0]
        shard = shard_linear_index(axes)
        counts = _mask_pad_counts(match(data_local, queries),
                                  shard * n_local, n_objects)
        local = select_topk(counts, params)
        gids = jnp.where(local.ids >= 0, local.ids + shard * n_local, -1)
        # Gather every shard's candidate buffer: [S, Q, k].
        all_ids = jax.lax.all_gather(gids, axis_name=axes, axis=0, tiled=False)
        all_counts = jax.lax.all_gather(local.counts, axis_name=axes, axis=0, tiled=False)
        merged = _merge.merge_topk(all_ids, all_counts, params.k)
        return merged

    sharded = shard_map_compat(
        _local, mesh,
        in_specs=(P(axes), P(None, None)),
        out_specs=_out_specs(),
    )
    return jax.jit(sharded)


def data_sharding(mesh: jax.sharding.Mesh) -> jax.sharding.NamedSharding:
    """NamedSharding for the object-partitioned data matrix [N, ...]."""
    return jax.sharding.NamedSharding(mesh, P(tuple(mesh.axis_names)))


def replicated(mesh: jax.sharding.Mesh, ndim: int) -> jax.sharding.NamedSharding:
    return jax.sharding.NamedSharding(mesh, P(*([None] * ndim)))


def make_hierarchical_search_step(
    mesh: jax.sharding.Mesh,
    params: SearchParams,
    match_fn: MatchLike,
    n_objects: int | None = None,
):
    """Two-level merge variant: reduce candidate buffers inside a pod first
    (cheap ICI), then across pods (expensive DCN) -- merge order does not
    change the result (merge is associative on partitioned objects), but the
    inter-pod traffic drops from S*Q*k to P_pods*Q*k pairs.

    Only meaningful on meshes with a leading "pod" axis; falls back to the
    flat merge otherwise.  `n_objects` masks segmented-layout pad rows,
    exactly as in `make_search_step`.
    """
    axes = tuple(mesh.axis_names)
    if axes[0] != "pod":
        return make_search_step(mesh, params, match_fn, n_objects=n_objects)
    inner_axes = axes[1:]
    match = _engines.resolve_match_fn(match_fn, params.use_kernel)

    def _local(data_local: jnp.ndarray, queries: Any) -> TopKResult:
        n_local = data_local.shape[0]
        shard = shard_linear_index(axes)
        counts = _mask_pad_counts(match(data_local, queries),
                                  shard * n_local, n_objects)
        local = select_topk(counts, params)
        gids = jnp.where(local.ids >= 0, local.ids + shard * n_local, -1)
        # level 1: merge within the pod (over data/model axes).
        ids_in = jax.lax.all_gather(gids, axis_name=inner_axes, axis=0, tiled=False)
        cnt_in = jax.lax.all_gather(local.counts, axis_name=inner_axes, axis=0, tiled=False)
        pod_merged = _merge.merge_topk(ids_in, cnt_in, params.k)
        # level 2: merge across pods.
        ids_out = jax.lax.all_gather(pod_merged.ids, axis_name=("pod",), axis=0, tiled=False)
        cnt_out = jax.lax.all_gather(pod_merged.counts, axis_name=("pod",), axis=0, tiled=False)
        return _merge.merge_topk(ids_out, cnt_out, params.k)

    sharded = shard_map_compat(
        _local, mesh,
        in_specs=(P(axes), P(None, None)),
        out_specs=_out_specs(),
    )
    return jax.jit(sharded)
