"""Hardware-aware plan autotuner: measured cost for tile/layout/routing knobs.

GENIE's pipeline runs at the hardware roofline only when its discrete knobs
match the machine (PAPER.md section 6): kernel tile sizes (the tile_q /
tile_n / tile_v / tile_m kwargs kernels/ops.py accepts but nothing drove),
fused vs. unfused packed match, SEGMENTED vs. MULTILOAD-host part layout,
the per-part `candidate_cap`, and the routing probe width `nprobe`.  The
right numbers differ per backend, engine, and corpus shape -- Faiss makes
the same point for GPU similarity search (PAPERS.md) -- so this module
closes the loop by *measuring*:

  * `tune()` greedily walks the knob space one axis at a time, timing real
    executions of real plans through `core.plan.execute` with
    `block_until_ready` (median of `repeats`, warmup pays compile), and
    never adopts a knob that does not beat the incumbent;
  * winners persist as `TunedEntry` rows in an `AutotuneCache` -- a JSON
    file keyed on a hardware fingerprint (platform, device kind, device
    count, memory) and a corpus-shape bucket, so tuning runs once per
    machine and a cache copied to different hardware silently disables
    itself;
  * `plan_search(autotune=...)` consults the cache via `consult()` and
    fills only the knobs the caller left unset; a miss (or fingerprint
    mismatch) keeps today's defaults, so tuned serving can never be worse
    than untuned by construction -- `tune()` stores the default knobs when
    no candidate beats them.

`price_plan()` additionally offers the lower-and-cost estimate (XLA
cost_analysis flops/bytes) folded in from the old benchmarks/hillclimb.py,
for ranking candidates without paying execution.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import time
from pathlib import Path
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.core import engines as _engines
from repro.core import plan as _plan
from repro.core.routing import Routing
from repro.core.types import Engine, SignatureLayout, TopKMethod

# ---------------------------------------------------------------------------
# Hardware fingerprint + shape bucketing (the cache key axes)
# ---------------------------------------------------------------------------

CACHE_VERSION = 1
# Mirrors tools/genielint config.vmem_budget_bytes: candidate tiles whose
# estimated VMEM working set exceeds this are never even measured.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_CACHE_ENV = "GENIE_AUTOTUNE_CACHE"


def hardware_fingerprint() -> dict:
    """Identity of the machine a measurement is valid for.

    Platform + device kind + device count + per-device memory: a tuned tile
    size is a statement about one memory hierarchy, so any of these changing
    invalidates the cache (lookup simply returns None -> default knobs).
    """
    devices = jax.devices()
    dev = devices[0]
    memory = None
    stats_fn = getattr(dev, "memory_stats", None)
    if stats_fn is not None:
        try:
            stats = stats_fn()
            if stats:
                memory = int(stats.get("bytes_limit", 0)) or None
        except (RuntimeError, NotImplementedError):
            memory = None  # backends without allocator stats (CPU)
    return {
        "platform": jax.default_backend(),
        "device_kind": str(dev.device_kind),
        "device_count": len(devices),
        "memory_bytes": memory,
        "jax": jax.__version__,
    }


def shape_bucket(n: int) -> int:
    """Corpus-shape bucket: next power of two >= n (floor 1).

    A measurement at n=100_000 prices n=120_000 fine; bucketing keeps the
    cache small and lookups stable as a corpus grows within its bucket.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"shape_bucket needs n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


# ---------------------------------------------------------------------------
# TunedEntry + JSON cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TunedEntry:
    """One measured winner: the knob set for (engine, layout, shape bucket).

    `layout` is the tuned part-structure choice ("segmented" /
    "multiload_host"; None = caller's layout stands).  `fused_match` False
    suppresses the fused packed kernel even where gating allows it; None
    leaves the default gating alone.  `speedup` is default_us/measured_us
    from the final head-to-head -- 1.0 entries record "defaults already
    win here", which stops re-tuning from re-measuring a settled bucket.
    """

    engine: str
    signature_layout: str
    n_bucket: int
    w_bucket: int
    tile_overrides: tuple = ()
    fused_match: Optional[bool] = None
    layout: Optional[str] = None
    candidate_cap: Optional[int] = None
    nprobe: Optional[int] = None
    measured_us: float = 0.0
    default_us: float = 0.0
    speedup: float = 1.0

    def key(self) -> str:
        return cache_key(self.engine, self.signature_layout,
                         self.n_bucket, self.w_bucket)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["tile_overrides"] = dict(self.tile_overrides)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TunedEntry":
        d = dict(d)
        d["tile_overrides"] = _engines.canonical_tile_overrides(
            d.get("tile_overrides") or {})
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def cache_key(engine: Engine | str, signature_layout: SignatureLayout | str,
              n_bucket: int, w_bucket: int) -> str:
    e = engine.value if isinstance(engine, Engine) else str(engine)
    s = (signature_layout.value if isinstance(signature_layout, SignatureLayout)
         else str(signature_layout))
    return f"{e}|{s}|{int(n_bucket)}|{int(w_bucket)}"


def default_cache_path() -> Path:
    env = os.environ.get(_CACHE_ENV)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "genie" / "autotune.json"


class AutotuneCache:
    """JSON-persisted map of `TunedEntry` rows, gated on the fingerprint.

    `path=None` keeps the cache in memory (tests, one-shot tuning runs).
    A load failure of any kind degrades to an empty cache -- autotuning is
    an accelerator, never a correctness dependency.
    """

    def __init__(self, path: Optional[os.PathLike | str] = None,
                 fingerprint: Optional[dict] = None):
        self.path = Path(path) if path is not None else None
        self.fingerprint = fingerprint or hardware_fingerprint()
        self.entries: dict[str, TunedEntry] = {}
        if self.path is not None:
            self.load()

    def compatible(self) -> bool:
        """True when the stored fingerprint matches this machine."""
        return self.fingerprint == hardware_fingerprint()

    def load(self) -> None:
        if self.path is None or not self.path.exists():
            return
        try:
            raw = json.loads(self.path.read_text())
            if raw.get("version") != CACHE_VERSION:
                return
            self.fingerprint = dict(raw["fingerprint"])
            self.entries = {
                k: TunedEntry.from_dict(v)
                for k, v in raw.get("entries", {}).items()
            }
        except (OSError, json.JSONDecodeError, KeyError, TypeError,
                ValueError):
            # unreadable / stale-schema cache: fall back to empty (defaults)
            self.fingerprint = hardware_fingerprint()
            self.entries = {}

    def save(self) -> None:
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "version": CACHE_VERSION,
            "fingerprint": self.fingerprint,
            "entries": {k: v.to_dict() for k, v in self.entries.items()},
        }
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        tmp.write_text(json.dumps(payload, indent=2, sort_keys=True))
        tmp.replace(self.path)

    def put(self, entry: TunedEntry) -> None:
        self.entries[entry.key()] = entry

    def lookup(self, engine: Engine | str,
               signature_layout: SignatureLayout | str,
               n: Optional[int], width: Optional[int] = None
               ) -> Optional[TunedEntry]:
        """The tuned entry for this shape, or None (= keep defaults).

        With `width` the lookup is exact; without it, any width bucket
        tuned for (engine, layout, n bucket) serves, best speedup first.
        Fingerprint mismatch -> None unconditionally.
        """
        if n is None or not self.compatible():
            return None
        nb = shape_bucket(n)
        if width is not None:
            return self.entries.get(
                cache_key(engine, signature_layout, nb, shape_bucket(width)))
        prefix = cache_key(engine, signature_layout, nb, 1).rsplit("|", 1)[0]
        hits = [v for k, v in self.entries.items()
                if k.rsplit("|", 1)[0] == prefix]
        if not hits:
            return None
        return max(hits, key=lambda e: e.speedup)


_RESOLVED: dict[str, AutotuneCache] = {}


def resolve_cache(spec: Any) -> Optional[AutotuneCache]:
    """`autotune=` argument -> cache: True = the default per-user path,
    a str/Path = that file, an AutotuneCache = itself, None/False = off.
    File-backed caches are memoized per path so plan_search does not
    re-read JSON per query."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, AutotuneCache):
        return spec
    path = default_cache_path() if spec is True else Path(spec)
    key = str(path)
    cache = _RESOLVED.get(key)
    if cache is None:
        cache = AutotuneCache(path)
        _RESOLVED[key] = cache
    return cache


def clear_resolved_caches() -> None:
    """Drop memoized file-backed caches (tests that rewrite cache files)."""
    _RESOLVED.clear()


def consult(spec: Any, *, engine: Engine | str,
            signature_layout: SignatureLayout | str,
            n: Optional[int], width: Optional[int] = None
            ) -> Optional[TunedEntry]:
    """plan_search's door: resolve the autotune spec and look the shape up.
    Any miss -- no cache, no entry, wrong machine -- returns None and the
    plan keeps its defaults."""
    cache = resolve_cache(spec)
    if cache is None:
        return None
    return cache.lookup(engine, signature_layout, n, width)


# ---------------------------------------------------------------------------
# Platform / XLA setup (SNIPPETS.md snippet 1 pattern)
# ---------------------------------------------------------------------------


def setup_platform(platform: Optional[str] = None,
                   host_devices: Optional[int] = None,
                   extra_xla_flags: Optional[str] = None) -> None:
    """Apply platform/XLA startup configuration.

    Only takes effect before the first JAX computation initialises the
    backend -- call it at process start (serve startup, benchmark mains).
    `host_devices` sets --xla_force_host_platform_device_count (the mesh
    tests' many-device CPU trick) *opt-in*, replacing the import-time
    hard-coding the old hillclimb benchmark did.
    """
    flags = []
    if host_devices is not None:
        n = int(host_devices)
        if n < 1:
            raise ValueError(f"host_devices must be >= 1, got {n}")
        flags.append(f"--xla_force_host_platform_device_count={n}")
    if extra_xla_flags:
        flags.append(str(extra_xla_flags))
    if flags:
        existing = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = " ".join(
            ([existing] if existing else []) + flags)
    if platform is not None:
        jax.config.update("jax_platform_name", platform)


# ---------------------------------------------------------------------------
# Measurement + pricing
# ---------------------------------------------------------------------------


def _median_us(fn: Callable[[], Any], repeats: int, warmup: int) -> float:
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(statistics.median(samples))


def measure_plan(plan: "_plan.QueryPlan", data, queries, *,
                 router=None, route_queries=None,
                 repeats: int = 3, warmup: int = 1) -> float:
    """Median wall microseconds of one real execution of `plan` (the same
    `core.plan.execute` door serving uses), device-synchronised."""
    def run():
        return _plan.execute(plan, data, queries, router=router,
                             route_queries=route_queries)
    return _median_us(run, repeats, warmup)


def compare_plans(plan_a: "_plan.QueryPlan", plan_b: "_plan.QueryPlan",
                  data, queries, *, router=None, route_queries=None,
                  rounds: int = 5) -> tuple[float, float]:
    """Interleaved head-to-head: (median_us_a, median_us_b).

    Sequential timing is biased on a warming machine (whichever plan runs
    last wins for free); alternating single executions after a joint warmup
    cancels the drift, so this is the arbiter `tune()` and the benchmark
    trust for the final tuned-vs-default verdict.
    """
    def runner(p):
        def run():
            return _plan.execute(p, data, queries, router=router,
                                 route_queries=route_queries)
        return run
    fa, fb = runner(plan_a), runner(plan_b)
    jax.block_until_ready(fa())
    jax.block_until_ready(fb())
    a_s, b_s = [], []
    for _ in range(max(rounds, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        a_s.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(fb())
        b_s.append((time.perf_counter() - t0) * 1e6)
    return float(statistics.median(a_s)), float(statistics.median(b_s))


def price_plan(plan: "_plan.QueryPlan", data, queries, *,
               mode: str = "measure", router=None, route_queries=None,
               repeats: int = 3, warmup: int = 1) -> dict:
    """Price one candidate plan.

    mode="measure": run it (measure_plan) -> {"p50_us": ...}.
    mode="lower": lower+compile the single-program executable and read the
    XLA cost model (flops / bytes accessed) without executing -- the
    lower-and-cost loop folded in from the old benchmarks/hillclimb.py.
    Host-loop layouts have no single lowerable program and reject "lower".
    """
    if mode == "measure":
        return {
            "mode": "measure",
            "p50_us": measure_plan(plan, data, queries, router=router,
                                   route_queries=route_queries,
                                   repeats=repeats, warmup=warmup),
        }
    if mode != "lower":
        raise ValueError(f"mode must be 'measure' or 'lower', got {mode!r}")
    if plan.layout not in (_plan.Layout.MONOLITHIC, _plan.Layout.MULTILOAD) \
            or plan.host_loop:
        raise ValueError(
            f"mode='lower' needs a single lowerable program; a "
            f"{plan.layout.value}{' host-loop' if plan.host_loop else ''} "
            f"plan is host-orchestrated -- price it with mode='measure'"
        )
    fn = _plan.executable(plan)
    lowered = fn.lower(data, queries)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jaxlibs wrap it in a list
        cost = cost[0] if cost else {}
    cost = cost or {}
    return {
        "mode": "lower",
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "cost_keys": sorted(cost)[:16],
    }


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------

_TILE_CANDIDATES = {
    "tile_q": (8, 16, 32, 64, 128, 256, 512),
    "tile_n": (128, 256, 512, 1024, 2048),
    "tile_v": (128, 256, 512, 1024),
    "tile_m": (128, 256, 512, 1024),
}
# Greedy axis order: the object axis dominates grid shape, then queries,
# then the in-kernel chunk axes.
_TILE_AXIS_ORDER = ("tile_n", "tile_q", "tile_v", "tile_m")


def _effective_tile(size: int, preferred: int, align: int) -> int:
    """What pick_tile will actually use -- dedupes candidates that clamp to
    the same grid (e.g. tile_n=1024 and 2048 over a 600-row corpus)."""
    from repro.kernels.common import pick_tile
    return pick_tile(size, preferred, align)


def _vmem_estimate(tiles: dict, q: int, n: int, width: int) -> int:
    """Rough per-grid-step VMEM working set: a [tile_q, W] query window, a
    [tile_n, W] data window, and the [tile_q, tile_n] count tile, int32.
    Conservative on purpose -- it only prunes candidates, never admits."""
    tq = tiles.get("tile_q", 128)
    tn = tiles.get("tile_n", 256)
    w = min(width, tiles.get("tile_v", tiles.get("tile_m", width)))
    tq = min(tq, max(q, 8))
    tn = min(tn, max(n, 128))
    return 4 * (tq * w + tn * w + tq * tn)


def tile_candidates(knob: str, dim: int, *,
                    vmem_budget: int = VMEM_BUDGET_BYTES) -> list[int]:
    """Deduped candidate values for one knob against its actual dim."""
    align = _engines.TILE_ALIGN[knob]
    seen, out = set(), []
    for cand in _TILE_CANDIDATES[knob]:
        eff = _effective_tile(dim, cand, align)
        if eff in seen:
            continue
        seen.add(eff)
        out.append(cand)
    return out


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def _split_parts(data, part_rows: Sequence[int]) -> list:
    parts, off = [], 0
    for r in part_rows:
        parts.append(data[off:off + r])
        off += r
    if off != data.shape[0]:
        raise ValueError(
            f"part_rows {tuple(part_rows)} covers {off} rows but data has "
            f"{data.shape[0]}")
    return parts


def tune(engine: Engine | str | _engines.MatchModel, data, queries, k: int,
         max_count: Optional[int] = None, *,
         signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
         method: TopKMethod | str = TopKMethod.CPQ,
         part_rows: Optional[Sequence[int]] = None,
         router=None, routing: Routing | str = Routing.NONE,
         candidate_caps: Sequence[Optional[int]] = (),
         budget: int = 32, repeats: int = 3, warmup: int = 1,
         vmem_budget: int = VMEM_BUDGET_BYTES,
         cache: Optional[AutotuneCache] = None, save: bool = True,
         prepared: bool = False, route_queries=None,
         ) -> TunedEntry:
    """Measure-and-pick the knob set for one (engine, layout, shape).

    `data` / `queries` are raw engine inputs (`MatchModel.example` form);
    preparation and packing happen here exactly as GenieIndex does them.
    `prepared=True` instead takes `data` already in the stored layout (the
    full array; packed words for PACKED) and `queries` as the canonical
    stored-layout pytree -- the serving path, whose sealed segments cannot
    be un-packed; it requires an explicit `max_count` and, for routed
    PACKED tuning, `route_queries` (the canonical WIDE pytree the router
    scores).  With `part_rows` the search runs part-structured and adds the
    layout axis (SEGMENTED vs MULTILOAD host loop -- both stream the same
    per-part arrays, so the choice is purely a merge-orchestration
    measurement) and, given `router` + `routing`, the nprobe axis.
    `budget` caps measured candidates; the default-knob plan is always
    measured first as the baseline, and the returned entry falls back to
    default knobs whenever no candidate beats it (tuned can never regress).

    The winning entry is put (and saved) into `cache` when given.
    """
    model = engine if isinstance(engine, _engines.MatchModel) \
        else _engines.get(engine)
    sig_layout = model.require_layout(signature_layout)
    method = TopKMethod(method)
    routing = Routing(routing)

    if prepared:
        if max_count is None:
            raise ValueError(
                "tune(prepared=True) needs an explicit max_count; the "
                "stored-layout array cannot derive the count bound")
        stored, q_stored, mc = data, queries, int(max_count)
        route_q = route_queries
    else:
        wide = model.prepare_data(data)
        mc = model.resolve_max_count(wide, max_count)
        stored = model.pack_data(wide) if sig_layout is SignatureLayout.PACKED \
            else wide
        q_stored = model.prepare_queries_for(queries, sig_layout)
        route_q = (model.prepare_queries(queries)
                   if sig_layout is SignatureLayout.PACKED else None)
    n, width = int(stored.shape[0]), int(stored.shape[1])
    n_q = int(np.asarray(jax.tree_util.tree_leaves(q_stored)[0]).shape[0])

    part_rows = tuple(int(r) for r in part_rows) if part_rows else None
    base_layout = _plan.Layout.SEGMENTED if part_rows else _plan.Layout.MONOLITHIC
    exec_data = _split_parts(stored, part_rows) if part_rows else stored

    knobs = model.tile_knobs(True, sig_layout)
    if sig_layout is SignatureLayout.PACKED:
        knobs = knobs | model.tile_knobs(True, sig_layout, fused=True)
    dims = {"tile_q": n_q, "tile_n": n, "tile_v": width, "tile_m": width}

    state = {
        "tiles": {}, "fused": None, "candidate_cap": None,
        "layout": base_layout, "host_loop": False, "nprobe": None,
    }

    def make_plan(st):
        p = _plan.plan_search(
            model, k, mc,
            layout=st["layout"], part_rows=part_rows,
            method=method, candidate_cap=st["candidate_cap"],
            use_kernel=True, host_loop=st["host_loop"],
            signature_layout=sig_layout,
            routing=routing if st["layout"] is not _plan.Layout.MONOLITHIC
            else Routing.NONE,
            nprobe=st["nprobe"],
            tile_overrides=st["tiles"] or None,
        )
        if st["fused"] is False and p.fused_match is not None:
            p = dataclasses.replace(p, fused_match=None)
        return p

    def run(st):
        return measure_plan(make_plan(st), exec_data, q_stored,
                            router=router, route_queries=route_q,
                            repeats=repeats, warmup=warmup)

    trials = 0
    default_us = run(state)
    best, best_us = dict(state, tiles=dict(state["tiles"])), default_us

    def try_state(st):
        nonlocal trials, best, best_us
        if trials >= budget:
            return
        trials += 1
        # every trial is an interleaved head-to-head against the incumbent:
        # a solo sequential measurement drifts with machine warmup, so the
        # sweep would crown whichever candidate happened to run at a calm
        # moment.  Re-anchor the incumbent's clock from the same interleave
        # so stale timings never survive the sweep.
        inc_us, cand_us = compare_plans(
            make_plan(best), make_plan(st), exec_data, q_stored,
            router=router, route_queries=route_q, rounds=max(repeats, 2))
        best_us = inc_us
        if cand_us < inc_us:
            best, best_us = dict(st, tiles=dict(st["tiles"])), cand_us

    # axis 1: tile sizes, greedy per knob
    for knob in _TILE_AXIS_ORDER:
        if knob not in knobs:
            continue
        for cand in tile_candidates(knob, dims[knob]):
            tiles = dict(best["tiles"])
            tiles[knob] = cand
            if _vmem_estimate(tiles, n_q, n, width) > vmem_budget:
                continue
            try_state(dict(best, tiles=tiles))

    # axis 2: fused packed kernel off (on is the gated default)
    if sig_layout is SignatureLayout.PACKED \
            and make_plan(best).fused_match is not None:
        try_state(dict(best, tiles=dict(best["tiles"]), fused=False))

    # axis 3: candidate_cap
    for cap in candidate_caps:
        try_state(dict(best, tiles=dict(best["tiles"]),
                       candidate_cap=None if cap is None else int(cap)))

    # axis 4: part layout -- SEGMENTED vs MULTILOAD host loop stream the
    # same per-part arrays; only the merge orchestration differs
    if part_rows:
        try_state(dict(best, tiles=dict(best["tiles"]),
                       layout=_plan.Layout.MULTILOAD, host_loop=True))

    # axis 5: routing probe width
    if part_rows and router is not None and routing is not Routing.NONE:
        for cand in (1, 2, 4, 8, 16):
            if cand > len(part_rows):
                break
            try_state(dict(best, tiles=dict(best["tiles"]), nprobe=cand))

    # head-to-head: interleaved re-measure of winner vs default (sequential
    # timing on a warming machine favours whoever runs last); keep defaults
    # unless the winner still wins
    default_state = {"tiles": {}, "fused": None, "candidate_cap": None,
                     "layout": base_layout, "host_loop": False, "nprobe": None}
    if best != default_state:
        default_us, best_us = compare_plans(
            make_plan(default_state), make_plan(best), exec_data, q_stored,
            router=router, route_queries=route_q,
            rounds=max(repeats, 3))
    if best_us >= default_us:
        best = default_state
        best_us = default_us

    tuned_layout = None
    if part_rows:
        tuned_layout = ("multiload_host"
                        if best["layout"] is _plan.Layout.MULTILOAD
                        else "segmented")
    entry = TunedEntry(
        engine=model.engine.value,
        signature_layout=sig_layout.value,
        n_bucket=shape_bucket(n),
        w_bucket=shape_bucket(width),
        tile_overrides=_engines.canonical_tile_overrides(best["tiles"]),
        fused_match=best["fused"],
        layout=tuned_layout,
        candidate_cap=best["candidate_cap"],
        nprobe=best["nprobe"],
        measured_us=best_us,
        default_us=default_us,
        speedup=(default_us / best_us) if best_us > 0 else 1.0,
    )
    if cache is not None:
        cache.put(entry)
        if save:
            cache.save()
    return entry
