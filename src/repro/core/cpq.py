"""c-PQ: Count Priority Queue (paper section III-C), TPU-native formulation.

The paper's c-PQ keeps a dense low-bit Bitmap Counter for every object, a Gate
(ZipperArray ZA + AuditThreshold AT) fed by atomic updates, and a small Hash
Table holding only objects whose count passed AT.  Theorem 3.1: when the scan
finishes, ZA[AT] < k <= ZA[AT-1], the k-th match count MC_k == AT - 1, and the
top-k candidates all sit in the Hash Table (|HT| = O(k * AT)).

TPU adaptation (DESIGN.md section 2): counts live in a bounded domain
[0, max_count], so the Gate state is reconstructed *exactly* from a count
histogram -- ZA[t] == #(count_n >= t) == suffix-sum of the histogram --
without any atomics:

  phase 1 (histogram):  hist[q, t] = #(counts[q, n] == t)   (Pallas kernel)
  phase 2 (gate):       AT = min(t >= 1 : ZA[t] < k);  threshold = AT - 1
  phase 3 (hash table): masked two-class compaction (strict > threshold first,
                        then ties == threshold) into a fixed buffer of size cap
                        -- the Hash-Table analogue; a single scan, no sort of N.

Only the final cap-sized buffer (cap ~ 2k << N) is ordered, reproducing the
paper's "scan the small HT once" property.  Exactness versus a full sort is
property-tested in tests/test_cpq.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import SearchParams, TopKResult


def count_histogram(counts: jnp.ndarray, max_count: int, bin_chunk: int = 8) -> jnp.ndarray:
    """hist[q, t] = #{n : counts[q, n] == t},  t in [0, max_count].

    lax.scan over bin chunks keeps the one-hot temp at [Q, N, bin_chunk]
    (a full [Q, N, max_count+1] one-hot is ~17 GB/device for the paper-scale
    SIFT cell; the Pallas kernel streams N tiles instead)."""
    nbins = max_count + 1
    c = counts.astype(jnp.int32)
    n_chunks = -(-nbins // bin_chunk)

    def step(_, start):
        bins = start + jnp.arange(bin_chunk, dtype=jnp.int32)
        part = jnp.sum((c[..., None] == bins).astype(jnp.int8), axis=1)
        return None, part.astype(jnp.int32)                  # [Q, bin_chunk]

    _, parts = jax.lax.scan(
        step, None, jnp.arange(n_chunks, dtype=jnp.int32) * bin_chunk
    )
    hist = jnp.moveaxis(parts, 0, 1).reshape(c.shape[0], n_chunks * bin_chunk)
    return hist[:, :nbins]


def zipper_array(hist: jnp.ndarray) -> jnp.ndarray:
    """ZA[q, t] = #{n : count >= t} (suffix sum of hist over the count axis)."""
    rev = jnp.flip(hist, axis=-1)
    return jnp.flip(jnp.cumsum(rev, axis=-1), axis=-1)


def audit_threshold(hist: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gate: AT[q] = min{t >= 1 : ZA[t] < k} (== max_count+1 when none).

    Returns (at, threshold) with threshold = AT - 1 == MC_k (Theorem 3.1).
    """
    za = zipper_array(hist)                      # [Q, max_count+1]
    max_count = hist.shape[-1] - 1
    below = za[:, 1:] < k                        # t = 1 .. max_count
    any_below = jnp.any(below, axis=-1)
    first = jnp.argmax(below, axis=-1) + 1       # first t with ZA[t] < k
    at = jnp.where(any_below, first, max_count + 1).astype(jnp.int32)
    return at, at - 1


def _compact_candidates(
    counts: jnp.ndarray, threshold: jnp.ndarray, cap: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two-class masked compaction into a cap-sized buffer per query.

    Objects with count > threshold ("strict", provably < k of them by the Gate)
    are written first; ties (== threshold) fill the remaining slots in id order
    (the paper breaks ties randomly).  Returns (ids [Q, cap], vals [Q, cap]),
    empty slots marked id=-1, val=-1.
    """
    q, n = counts.shape
    c = counts.astype(jnp.int32)
    thr = threshold[:, None]
    strict = c > thr
    tie = c == thr
    n_strict = jnp.sum(strict.astype(jnp.int32), axis=-1, keepdims=True)
    pos_strict = jnp.cumsum(strict.astype(jnp.int32), axis=-1) - 1
    pos_tie = n_strict + jnp.cumsum(tie.astype(jnp.int32), axis=-1) - 1
    pos = jnp.where(strict, pos_strict, jnp.where(tie, pos_tie, cap))
    pos = jnp.minimum(pos, cap)                  # cap slot == drop
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (q, n))
    out_ids = jnp.full((q, cap + 1), -1, dtype=jnp.int32)
    out_vals = jnp.full((q, cap + 1), -1, dtype=jnp.int32)
    out_ids = jax.vmap(lambda o, p, v: o.at[p].set(v, mode="drop"))(out_ids, pos, ids)
    out_vals = jax.vmap(lambda o, p, v: o.at[p].set(v, mode="drop"))(out_vals, pos, c)
    return out_ids[:, :cap], out_vals[:, :cap]


def topk_from_candidates(ids: jnp.ndarray, vals: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Order a small candidate buffer by (count desc, id asc) and take k.

    This is the "scan the Hash Table once" step: the buffer is tiny (cap or a
    merge of per-shard caps), so the sort cost is O(cap log cap) independent
    of N.
    """
    vals = vals.astype(jnp.int32)
    # Stable argsort on -vals keeps id-ascending order within equal counts
    # (buffers are filled in id order).
    order = jnp.argsort(-vals, axis=-1, stable=True)
    top = order[..., :k]
    return (
        jnp.take_along_axis(ids, top, axis=-1),
        jnp.take_along_axis(vals, top, axis=-1),
    )


def cpq_select(
    counts: jnp.ndarray,
    params: SearchParams,
    hist: jnp.ndarray | None = None,
) -> TopKResult:
    """Exact top-k by match count via the c-PQ gate.  counts: int [Q, N].

    `hist` may be supplied by the fused Pallas kernel (kernels/cpq_hist); when
    None it is computed with the pure-jnp reference.
    """
    if hist is None:
        hist = count_histogram(counts, params.max_count)
    _, threshold = audit_threshold(hist, params.k)
    cap = params.cap()
    cand_ids, cand_vals = _compact_candidates(counts, threshold, cap)
    ids, vals = topk_from_candidates(cand_ids, cand_vals, params.k)
    return TopKResult(ids=ids, counts=vals, threshold=threshold)


def sort_select(counts: jnp.ndarray, params: SearchParams) -> TopKResult:
    """Baseline: full sort-based top-k (lax.top_k over all N)."""
    vals, ids = jax.lax.top_k(counts.astype(jnp.int32), params.k)
    return TopKResult(ids=ids.astype(jnp.int32), counts=vals, threshold=vals[:, -1])
