"""MatchModel registry: one descriptor per match-count engine.

GENIE's central claim is *genericity* -- one inverted-index machinery serving
many data types and similarity measures (paper section II).  This module makes
that claim structural: every engine (EQ, RANGE, MINSUM, IP, TANIMOTO, COSINE,
and any future measure) is a single `MatchModel` descriptor bundling

  * the reference match function (core/match.py -- the semantics oracle),
  * the Pallas kernel wrapper (kernels/ops.py -- the TPU hot path),
  * query canonicalisation (so every engine exposes the same
    ``fn(data, queries) -> counts[Q, N]`` signature; RANGE queries are the
    pytree ``(lo, hi)``),
  * data preparation + index statistics (what GenieIndex.build_* duplicated),
  * the count-dtype policy (Bitmap-Counter bit-bounding, paper III-C),
  * the multiload padding fill (a value that can never out-score real rows).

GenieIndex, core.multiload, core.distributed, and launch.dryrun all resolve
engines through `get()` -- there is exactly one dispatch point in the system.
Registering a new similarity measure is one `register(MatchModel(...))` call;
see docs/ENGINES.md for the contract and a worked example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import match as _match
from repro.core import packing as _packing
from repro.core.types import Engine, IndexStats, SignatureLayout

# Tile-knob alignment floors (kernels/common.py::pick_tile enforces them at
# dispatch): tile_q is a sublane dim (8), tile_n / tile_v / tile_m are lane
# dims (128) -- the TPU min-tile widths every kernel's BlockSpec assumes.
TILE_ALIGN: dict[str, int] = {
    "tile_q": 8,
    "tile_n": 128,
    "tile_v": 128,
    "tile_m": 128,
}


def canonical_tile_overrides(tile_overrides) -> tuple[tuple[str, int], ...]:
    """Normalise a mapping / pair-sequence of tile knobs to the sorted tuple
    form QueryPlan hashes on, validating names and alignment floors."""
    if tile_overrides is None:
        return ()
    items = (tile_overrides.items() if hasattr(tile_overrides, "items")
             else tile_overrides)
    out = []
    for name, value in items:
        name = str(name)
        if name not in TILE_ALIGN:
            raise ValueError(
                f"unknown tile knob {name!r}; known knobs: "
                f"{sorted(TILE_ALIGN)}"
            )
        value = int(value)
        if value < TILE_ALIGN[name]:
            raise ValueError(
                f"{name}={value} is below the alignment floor "
                f"{TILE_ALIGN[name]} (TPU min-tile width); tuned tiles must "
                f"be >= the floor"
            )
        out.append((name, value))
    if len({n for n, _ in out}) != len(out):
        raise ValueError(f"duplicate tile knob in {tile_overrides!r}")
    return tuple(sorted(out))


# Tile-bound match callables, memoized so two plans with equal
# (model, use_kernel, layout, overrides, fused) share ONE callable identity:
# QueryPlan hashes its match/fused_match fields, so memoisation here is what
# lets tuned plans hit the executable cache instead of re-tracing per call.
_TILED_FN_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class MatchModel:
    """Descriptor for one match-count engine (paper Definition 2.1).

    The canonical match signature is ``fn(data, queries) -> counts [Q, N]``
    where `queries` is this engine's canonical query pytree (produced by
    `prepare_queries`).  Both `reference` and `kernel` use it, so multiload,
    distributed sharding, and serving are engine-agnostic.
    """

    engine: Engine
    description: str
    # raw user data -> device-resident index array (dtype/canonical form)
    prepare_data: Callable[[Any], jnp.ndarray]
    # raw queries -> canonical query pytree of device arrays
    prepare_queries: Callable[[Any], Any]
    # pure-jnp reference semantics (core/match.py), canonical signature
    reference: Callable[[jnp.ndarray, Any], jnp.ndarray]
    # Pallas kernel wrapper (kernels/ops.py), canonical signature; lazily
    # imports the kernels so CPU-only uses never pay for them
    kernel: Callable[[jnp.ndarray, Any], jnp.ndarray]
    # index statistics: postings count for this data layout
    postings_count: Callable[[jnp.ndarray], int]
    # default count-domain bound, or None when the caller must supply one
    default_max_count: Callable[[jnp.ndarray], Optional[int]]
    # multiload row fill: padded rows must never beat real rows
    pad_value: Any = -1
    # seeded conformance data: (np rng, n, q) -> (raw_data, raw_queries,
    # max_count | None).  Engines that provide it get the engine-matrix
    # parity/pad/tie conformance tests (tests/test_engine_matrix.py) for free.
    example: Optional[Callable[[Any, int, int], tuple]] = None

    # -- PACKED signature layout (core/packing.py) --------------------------
    # All None/unset => the engine is WIDE-only and PACKED plans are rejected.
    # pack_data / pack_queries transform *prepared* (canonical WIDE) arrays
    # once at index-seal / query-canonicalisation time; packed_reference and
    # packed_kernel keep the canonical ``fn(data, queries) -> counts [Q, N]``
    # signature on the packed arrays, with counts bit-for-bit equal to WIDE.
    pack_data: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    pack_queries: Optional[Callable[[Any], Any]] = None
    packed_reference: Optional[Callable[[jnp.ndarray, Any], jnp.ndarray]] = None
    packed_kernel: Optional[Callable[[jnp.ndarray, Any], jnp.ndarray]] = None
    # fused match -> count -> per-tile local top-k on packed arrays:
    # fn(data, queries, k) -> (ids, counts) candidate buffers [Q, n_tiles*kc]
    # in per-tile (count desc, id asc) order, pads id -1 / count -1
    packed_fused_topk: Optional[Callable[[jnp.ndarray, Any, int], tuple]] = None
    # multiload row fill in the packed domain (same never-out-scores contract
    # as pad_value; pad rows are id-masked upstream regardless)
    packed_pad_value: Any = None
    # packed footprint in bytes, computed from the WIDE prepared array
    packed_bytes: Optional[Callable[[jnp.ndarray], int]] = None

    # -- tile knobs (core/autotune.py) --------------------------------------
    # The tile kwargs each kernel wrapper accepts (kernels/ops.py): the
    # autotuner's searchable axes for this engine.  Empty => the path takes
    # no tile overrides (reference fns never do).
    kernel_tile_knobs: frozenset = frozenset()
    packed_tile_knobs: frozenset = frozenset()
    packed_fused_tile_knobs: frozenset = frozenset()

    @property
    def supports_packed(self) -> bool:
        return self.pack_data is not None

    def require_layout(self, layout: SignatureLayout | str) -> SignatureLayout:
        layout = SignatureLayout(layout)
        if layout is SignatureLayout.PACKED and not self.supports_packed:
            raise ValueError(
                f"engine {self.engine.value!r} has no packed signature format; "
                f"use SignatureLayout.WIDE"
            )
        return layout

    def pad_value_for(self, layout: SignatureLayout | str) -> Any:
        if SignatureLayout(layout) is SignatureLayout.PACKED:
            self.require_layout(layout)
            return self.packed_pad_value
        return self.pad_value

    def tile_knobs(
        self,
        use_kernel: bool,
        signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
        fused: bool = False,
    ) -> frozenset:
        """The tile knob names this engine's dispatch path accepts."""
        if not use_kernel:
            return frozenset()
        if self.require_layout(signature_layout) is SignatureLayout.PACKED:
            return (self.packed_fused_tile_knobs if fused
                    else self.packed_tile_knobs)
        return self.kernel_tile_knobs

    def _tiled(self, base: Callable, overrides: tuple, knobs: frozenset,
               tag: str) -> Callable:
        """Memoized wrapper binding the tile kwargs `base` accepts.  Knobs the
        path does not take (e.g. tile_m on a fused kernel that chunks the
        signature axis internally) are dropped, so one tuned entry can drive
        both the count and fused dispatchers."""
        kw = {n: v for n, v in overrides if n in knobs}
        if not kw:
            return base
        key = (tag, self, base, tuple(sorted(kw.items())))
        fn = _TILED_FN_CACHE.get(key)
        if fn is None:
            if tag == "fused":
                def fn(data, queries, k, _base=base, _kw=kw):
                    return _base(data, queries, k, **_kw)
            else:
                def fn(data, queries, _base=base, _kw=kw):
                    return _base(data, queries, **_kw)
            _TILED_FN_CACHE[key] = fn
        return fn

    # -- dispatch -----------------------------------------------------------
    def match_fn(
        self,
        use_kernel: bool,
        signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
        tile_overrides: tuple = (),
    ) -> Callable[[jnp.ndarray, Any], jnp.ndarray]:
        """The canonical match callable for this engine (kernel or reference),
        operating on arrays in the given signature layout.  `tile_overrides`
        (canonical ``((knob, value), ...)`` pairs, see
        `canonical_tile_overrides`) bind kernel tile kwargs; the returned
        callable is memoized per override set so equal plans share one
        identity (the executable cache keys on it)."""
        layout = self.require_layout(signature_layout)
        if layout is SignatureLayout.PACKED:
            base = self.packed_kernel if use_kernel else self.packed_reference
        else:
            base = self.kernel if use_kernel else self.reference
        if not tile_overrides or not use_kernel:
            return base
        return self._tiled(base, tile_overrides,
                           self.tile_knobs(use_kernel, layout), "match")

    def fused_topk_fn(
        self,
        tile_overrides: tuple = (),
    ) -> Optional[Callable[[jnp.ndarray, Any, int], tuple]]:
        """The fused packed match->count->local-top-k callable with tile
        overrides bound (same memoisation contract as match_fn)."""
        if self.packed_fused_topk is None or not tile_overrides:
            return self.packed_fused_topk
        return self._tiled(self.packed_fused_topk, tile_overrides,
                           self.packed_fused_tile_knobs, "fused")

    def prepare_queries_for(
        self, queries: Any,
        signature_layout: SignatureLayout | str = SignatureLayout.WIDE,
    ) -> Any:
        """Raw queries -> canonical query pytree in the given layout
        (canonicalise WIDE first, then pack)."""
        q = self.prepare_queries(queries)
        if self.require_layout(signature_layout) is SignatureLayout.PACKED:
            q = self.pack_queries(q)
        return q

    def match_counts(self, data: jnp.ndarray, queries: Any, use_kernel: bool,
                     signature_layout: SignatureLayout | str = SignatureLayout.WIDE) -> jnp.ndarray:
        """counts int32 [Q, N]; `queries` may be raw (canonicalised here) and
        `data` must already be in `signature_layout`."""
        return self.match_fn(use_kernel, signature_layout)(
            data, self.prepare_queries_for(queries, signature_layout))

    # -- build-time policy --------------------------------------------------
    def build_stats(self, data: jnp.ndarray) -> IndexStats:
        """Index statistics from the *prepared WIDE* array (postings, count
        bounds, and the packed footprint all read the logical layout -- call
        this before pack_data, never on the packed array)."""
        wide_bytes = int(data.size) * data.dtype.itemsize
        return IndexStats(
            n_objects=int(data.shape[0]),
            n_lists=int(data.shape[1]),
            total_postings=int(self.postings_count(data)),
            bytes_device=wide_bytes,
            bytes_signatures_wide=wide_bytes,
            bytes_signatures_packed=(
                int(self.packed_bytes(data)) if self.packed_bytes else 0
            ),
            extra={"engine": self.engine.value},
        )

    def resolve_max_count(self, data: jnp.ndarray, max_count: Optional[int]) -> int:
        if max_count is not None:
            return int(max_count)
        derived = self.default_max_count(data)
        if derived is None:
            raise ValueError(
                f"engine {self.engine.value!r} has no derivable count bound; "
                f"pass max_count explicitly"
            )
        return int(derived)

    def count_dtype(self, max_count: int) -> jnp.dtype:
        """Bitmap-Counter policy: narrowest lossless count dtype (III-C)."""
        probe = _match.as_count_dtype(jnp.zeros((), jnp.int32), max_count)
        return probe.dtype

    def as_count_dtype(self, counts: jnp.ndarray, max_count: int) -> jnp.ndarray:
        return _match.as_count_dtype(counts, max_count)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[Engine, MatchModel] = {}


def register(model: MatchModel) -> MatchModel:
    """Register (or replace) the descriptor for `model.engine`."""
    _REGISTRY[model.engine] = model
    return model


def get(engine: Engine | str | MatchModel) -> MatchModel:
    """Resolve an Engine, its string value, or a MatchModel to a descriptor."""
    if isinstance(model := engine, MatchModel):
        return model
    eng = Engine(engine)
    try:
        return _REGISTRY[eng]
    except KeyError:
        raise KeyError(
            f"no MatchModel registered for engine {eng.value!r}; "
            f"known: {sorted(m.value for m in _REGISTRY)}"
        ) from None


def available() -> tuple[Engine, ...]:
    return tuple(_REGISTRY)


def resolve_match_fn(engine, use_kernel: bool = False,
                     signature_layout: SignatureLayout | str = SignatureLayout.WIDE):
    """Engine/str/MatchModel/callable -> canonical match callable.

    Raw callables pass through untouched (back-compat for code that hands a
    bare ``fn(data, queries)`` to distributed/multiload search) -- the caller
    owns the layout contract in that case.
    """
    if callable(engine) and not isinstance(engine, (MatchModel, Engine, str)):
        return engine
    return get(engine).match_fn(use_kernel, signature_layout)


# ---------------------------------------------------------------------------
# Built-in engines (paper sections IV-V)
# ---------------------------------------------------------------------------

def _kernel_eq(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.match_count(data, queries, **tiles)


def _kernel_range(data, queries, **tiles):
    from repro.kernels import ops as kops

    lo, hi = queries
    return kops.range_count(data, lo, hi, **tiles)


def _kernel_minsum(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.minsum_count(data, queries, **tiles)


def _kernel_ip(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.ip_count(data, queries, **tiles)


def _kernel_tanimoto(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.tanimoto_count(data, queries, **tiles)


def _kernel_cosine(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.cosine_count(data, queries, **tiles)


def _kernel_packed_cosine(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.packed_cosine_count(data, queries, **tiles)


def _kernel_packed_cosine_topk(data, queries, k, **tiles):
    from repro.kernels import ops as kops

    return kops.packed_cosine_topk(data, queries, k=k, **tiles)


def _kernel_packed_tanimoto(data, queries, **tiles):
    from repro.kernels import ops as kops

    return kops.packed_tanimoto_count(data, queries, **tiles)


def _kernel_packed_tanimoto_topk(data, queries, k, **tiles):
    from repro.kernels import ops as kops

    return kops.packed_tanimoto_topk(data, queries, k=k, **tiles)


def _sign_quantize(x) -> jnp.ndarray:
    """Raw vectors -> {-1, +1} int8 (floats by sign; {0,1} bits map to -1/+1)."""
    x = jnp.asarray(x)
    return jnp.where(x > 0, 1, -1).astype(jnp.int8)


register(MatchModel(
    engine=Engine.EQ,
    description="signature equality compare over LSH signatures int32 [N, m]",
    prepare_data=lambda x: jnp.asarray(x, dtype=jnp.int32),
    prepare_queries=lambda q: jnp.asarray(q, dtype=jnp.int32),
    reference=_match.match_eq,
    kernel=_kernel_eq,
    postings_count=lambda a: int(a.shape[0]) * int(a.shape[1]),
    default_max_count=lambda a: int(a.shape[1]),          # m hash functions
    pad_value=-1,                                          # never equals a sig
    example=lambda rng, n, q: (rng.integers(0, 8, (n, 16)).astype(np.int32),
                               rng.integers(0, 8, (q, 16)).astype(np.int32), None),
    kernel_tile_knobs=frozenset({"tile_q", "tile_n"}),
))

register(MatchModel(
    engine=Engine.RANGE,
    description="per-attribute interval predicate over discretized tuples int32 [N, d]",
    prepare_data=lambda x: jnp.asarray(x, dtype=jnp.int32),
    prepare_queries=lambda q: (jnp.asarray(q[0], dtype=jnp.int32),
                               jnp.asarray(q[1], dtype=jnp.int32)),
    reference=lambda d, q: _match.match_range(d, q[0], q[1]),
    kernel=_kernel_range,
    postings_count=lambda a: int(a.size),
    default_max_count=lambda a: int(a.shape[1]),          # #attributes
    pad_value=np.iinfo(np.int32).min,                     # below any query lo
    example=lambda rng, n, q: (
        rng.integers(0, 10, (n, 6)).astype(np.int32),
        (lambda lo: (lo, lo + 3))(rng.integers(0, 6, (q, 6)).astype(np.int32)),
        None),
    kernel_tile_knobs=frozenset({"tile_q", "tile_n"}),
))

register(MatchModel(
    engine=Engine.MINSUM,
    description="multiset intersection sum_v min(c_data, c_query) over count vectors [N, V]",
    prepare_data=lambda x: jnp.asarray(x, dtype=jnp.int32),
    prepare_queries=lambda q: jnp.asarray(q, dtype=jnp.int32),
    reference=_match.match_minsum,
    kernel=_kernel_minsum,
    postings_count=lambda a: int(np.asarray(jnp.sum(a))),
    default_max_count=lambda a: None,                     # caller supplies bound
    pad_value=-1,                                          # min(-1, q) sums < 0
    example=lambda rng, n, q: (rng.integers(0, 4, (n, 24)).astype(np.int32),
                               rng.integers(0, 4, (q, 24)).astype(np.int32), 96),
    kernel_tile_knobs=frozenset({"tile_q", "tile_n", "tile_v"}),
))

register(MatchModel(
    engine=Engine.IP,
    description="binary inner product on the MXU over word vectors [N, V]",
    prepare_data=jnp.asarray,                              # keep caller dtype
    prepare_queries=jnp.asarray,
    reference=_match.match_ip,
    kernel=_kernel_ip,
    postings_count=lambda a: int(np.asarray(jnp.sum(a.astype(jnp.int32)))),
    default_max_count=lambda a: None,                     # caller supplies bound
    pad_value=0,                                           # zero dot product
    example=lambda rng, n, q: (rng.integers(0, 2, (n, 32)).astype(np.int32),
                               rng.integers(0, 2, (q, 32)).astype(np.int32), 32),
    kernel_tile_knobs=frozenset({"tile_q", "tile_n", "tile_v"}),
))

register(MatchModel(
    engine=Engine.TANIMOTO,
    description="minhash collision count over set sketches int32 [N, m] (Jaccard MLE c/m)",
    prepare_data=lambda x: jnp.asarray(x, dtype=jnp.int32),
    prepare_queries=lambda q: jnp.asarray(q, dtype=jnp.int32),
    reference=_match.match_tanimoto,
    kernel=_kernel_tanimoto,
    postings_count=lambda a: int(a.shape[0]) * int(a.shape[1]),
    default_max_count=lambda a: int(a.shape[1]),          # m minhash functions
    pad_value=-1,                                          # outside bucket range
    example=lambda rng, n, q: (rng.integers(0, 64, (n, 20)).astype(np.int32),
                               rng.integers(0, 64, (q, 20)).astype(np.int32), None),
    # PACKED: uint8 bucket ids (rehash domain <= 253; 254/255 pad sentinels)
    pack_data=_packing.pack_buckets,
    pack_queries=_packing.pack_buckets,
    packed_reference=_packing.packed_tanimoto_match,
    packed_kernel=_kernel_packed_tanimoto,
    packed_fused_topk=_kernel_packed_tanimoto_topk,
    packed_pad_value=_packing.PACKED_BUCKET_PAD_DATA,      # never collides
    packed_bytes=_packing.packed_bytes_tanimoto,
    kernel_tile_knobs=frozenset({"tile_q", "tile_n", "tile_m"}),
    packed_tile_knobs=frozenset({"tile_q", "tile_n", "tile_m"}),
    # the fused kernel chunks the signature axis in VMEM itself: no tile_m
    packed_fused_tile_knobs=frozenset({"tile_q", "tile_n"}),
))

register(MatchModel(
    engine=Engine.COSINE,
    description="sign-agreement count of sign-quantized vectors {-1,+1} [N, V] on the MXU",
    prepare_data=_sign_quantize,
    prepare_queries=_sign_quantize,
    reference=_match.match_cosine,
    kernel=_kernel_cosine,
    postings_count=lambda a: int(a.size),                  # every sign is a posting
    default_max_count=lambda a: int(a.shape[1]),          # V sign agreements max
    pad_value=0,                                           # dot-neutral; id-masked
    example=lambda rng, n, q: (rng.standard_normal((n, 32)).astype(np.float32),
                               rng.standard_normal((q, 32)).astype(np.float32), None),
    # PACKED: 32 signs per int32 word, matched by XOR+popcount; query tail
    # bits 1 vs data tail bits 0 keep counts exact without knowing V
    pack_data=_packing.pack_signs_data,
    pack_queries=_packing.pack_signs_queries,
    packed_reference=_packing.packed_cosine_match,
    packed_kernel=_kernel_packed_cosine,
    packed_fused_topk=_kernel_packed_cosine_topk,
    packed_pad_value=0,                                    # all-zero words; id-masked
    packed_bytes=_packing.packed_bytes_cosine,
    kernel_tile_knobs=frozenset({"tile_q", "tile_n", "tile_v"}),
    # packed words chunk the bit axis in VMEM: only the [Q, N] tiles tune
    packed_tile_knobs=frozenset({"tile_q", "tile_n"}),
    packed_fused_tile_knobs=frozenset({"tile_q", "tile_n"}),
))
