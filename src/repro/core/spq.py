"""SPQ baseline: iterative bucket k-selection (paper appendix, after [9]).

This is the "GPU-SPQ / GEN-SPQ" competitor the paper benchmarks against
(Figs 9/10/13, Table IV): extract the top-k of a value array by repeatedly
partitioning the active value range into B buckets, locating the bucket that
contains the k-th largest element, saving everything above it, and recursing
into that bucket.  The paper reports convergence in 2-3 iterations; we run a
fixed number of narrowing iterations (enough for integer counts to collapse
the bucket width below 1) and then reuse the same threshold compaction as
c-PQ, which keeps the comparison about the *selection strategy* (range
narrowing over N vs. the bounded-count Gate).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cpq as _cpq
from repro.core.types import SearchParams, TopKResult


def spq_select(
    counts: jnp.ndarray,
    params: SearchParams,
    n_buckets: int = 32,
    n_iters: int = 4,
) -> TopKResult:
    """Bucket k-selection: counts int [Q, N] -> exact top-k."""
    q, n = counts.shape
    c = counts.astype(jnp.float32)
    k = params.k

    lo = jnp.min(c, axis=-1)                             # [Q] active range lower
    hi = jnp.max(c, axis=-1)                             # [Q] active range upper
    saved = jnp.zeros((q,), dtype=jnp.int32)             # elems strictly above range

    for _ in range(n_iters):
        width = jnp.maximum((hi - lo) / n_buckets, 1e-6)
        # bucket id of each element; elements outside [lo, hi] are clamped away
        b = jnp.clip(((c - lo[:, None]) / width[:, None]).astype(jnp.int32), -1, n_buckets)
        in_range = (c >= lo[:, None]) & (c <= hi[:, None])
        b = jnp.where(in_range, jnp.minimum(b, n_buckets - 1), -1)
        hist = jnp.sum(
            (b[..., None] == jnp.arange(n_buckets, dtype=jnp.int32)).astype(jnp.int32),
            axis=1,
        )                                                 # [Q, B]
        # suffix count of elements in bucket >= t
        suffix = jnp.flip(jnp.cumsum(jnp.flip(hist, -1), -1), -1)
        need = k - saved                                  # remaining to find
        # selected bucket: largest b* with suffix[b*] >= need
        ok = suffix >= need[:, None]
        bstar = jnp.where(
            jnp.any(ok, axis=-1),
            n_buckets - 1 - jnp.argmax(jnp.flip(ok, -1), axis=-1),
            0,
        )
        above = jnp.where(
            bstar + 1 < n_buckets,
            jnp.take_along_axis(suffix, jnp.minimum(bstar + 1, n_buckets - 1)[:, None], -1)[:, 0],
            0,
        )
        above = jnp.where(bstar + 1 < n_buckets, above, 0)
        saved = saved + above
        new_lo = lo + bstar.astype(jnp.float32) * width
        new_hi = new_lo + width
        lo, hi = new_lo, new_hi

    # For integer counts the final bucket width < 1, so ceil(lo) is the k-th
    # value; select with the shared compaction machinery.
    threshold = jnp.ceil(lo - 1e-4).astype(jnp.int32)
    cap = params.cap()
    cand_ids, cand_vals = _cpq._compact_candidates(counts, threshold, cap)
    ids, vals = _cpq.topk_from_candidates(cand_ids, cand_vals, params.k)
    return TopKResult(ids=ids, counts=vals, threshold=threshold)
