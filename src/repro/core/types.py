"""Core types for the GENIE match-count / top-k search framework."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional

import jax
import jax.numpy as jnp


class Engine(str, enum.Enum):
    """Match-count execution engines (see DESIGN.md section 2).

    EQ       -- signature equality compare (LSH-transformed data).
    RANGE    -- per-attribute interval predicate (relational data).
    MINSUM   -- multiset intersection  sum_v min(c_data, c_query)  (SA n-grams).
    IP       -- binary inner product on the MXU (SA documents / sets).
    TANIMOTO -- minhash collision count estimating Jaccard over sets (FLASH).
    COSINE   -- sign-agreement count of sign-quantized vectors on the MXU
                (simhash-angle cosine, Johnson et al. 1702.08734).
    """

    EQ = "eq"
    RANGE = "range"
    MINSUM = "minsum"
    IP = "ip"
    TANIMOTO = "tanimoto"
    COSINE = "cosine"


class TopKMethod(str, enum.Enum):
    CPQ = "cpq"          # the paper's c-PQ (histogram gate, Theorem 3.1)
    SPQ = "spq"          # baseline: bucket k-selection (paper appendix / GPU-SPQ)
    SORT = "sort"        # baseline: full lax.top_k (sort-based)


class SignatureLayout(str, enum.Enum):
    """Device-resident signature storage format (core/packing.py).

    WIDE    -- one signature slot per array element (the historical layout:
               int8 +-1 signs for COSINE, int32 bucket ids for TANIMOTO).
    PACKED  -- bit/byte-packed: COSINE signs become uint32-word bitfields
               matched by XOR+popcount (FLASH, Wang et al. 1709.01190),
               TANIMOTO bucket ids narrow to one byte matched by byte
               compare.  Counts are bit-for-bit identical to WIDE; only the
               bytes moved per object shrink (4-8x).  Engines without a
               packed format reject PACKED plans at build/plan time.
    """

    WIDE = "wide"
    PACKED = "packed"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TopKResult:
    """Result of a top-k match-count query batch.

    ids:       int32 [Q, k]  object ids (-1 padding when fewer than k objects).
    counts:    int32 [Q, k]  match-count values, non-increasing along k.
    threshold: int32 [Q]     AT-1 per Theorem 3.1 == match count of the k-th object.
    """

    ids: jnp.ndarray
    counts: jnp.ndarray
    threshold: jnp.ndarray

    @property
    def k(self) -> int:
        return self.ids.shape[-1]


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Static parameters of a GENIE search."""

    k: int
    max_count: int                 # count-domain bound (e.g. m for LSH, #attrs for tables)
    method: TopKMethod = TopKMethod.CPQ
    candidate_cap: Optional[int] = None  # capacity of the candidate buffer (default 2k)
    use_kernel: bool = True        # Pallas kernels (interpret=True off-TPU) vs pure jnp

    def cap(self) -> int:
        if self.candidate_cap is not None:
            return max(self.candidate_cap, self.k)
        return max(2 * self.k, self.k + 16)


@dataclasses.dataclass
class IndexStats:
    """Host-side statistics recorded at index-build time.

    The segment fields describe a SegmentedIndex (core/segments.py): a
    monolithic GenieIndex is the degenerate single-segment case
    (`n_segments=1`, empty per-segment lists, no compactions).
    """

    n_objects: int = 0
    n_lists: int = 0
    total_postings: int = 0
    max_list_len: int = 0
    bytes_device: int = 0
    build_seconds: float = 0.0
    # signature storage accounting: bytes the corpus occupies under each
    # layout (bytes_device equals whichever layout is actually resident;
    # bytes_signatures_packed is 0 for engines without a packed format)
    signature_layout: str = SignatureLayout.WIDE.value
    bytes_signatures_wide: int = 0
    bytes_signatures_packed: int = 0
    # per-segment build/compaction accounting (core/segments.py)
    n_segments: int = 1
    segment_rows: list[int] = dataclasses.field(default_factory=list)
    segment_build_seconds: list[float] = dataclasses.field(default_factory=list)
    compaction_count: int = 0
    compaction_seconds: float = 0.0
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)
