"""Coarse routing: prune segments/shards before the exact match phase.

Every query used to match against every segment on every shard -- O(N) device
work per query -- while the paper's inverted-index design exists precisely to
touch only the lists that can matter.  This module is the cluster-level
router in front of the exact engines (the Faiss IVF coarse quantizer of
Johnson et al. 1702.08734, GTS's tree over node summaries, 2404.00966): at
seal time each segment computes a compact `SegmentSummary` -- per-column
min/max bounds, a centroid over its signatures, and (for the bucketed
engines) a per-column bucket-occupancy sketch -- and at query time a `Router`
scores query signatures against all summaries to decide which segments can
still contain a top-k member.

The router's contract is an *upper bound*, not an estimate: for every engine
``upper_bound(summary, queries)[q] >= max_i count(row_i, query_q)`` over the
segment's rows.  That makes the three routing modes (`core/plan.py` threads
them through `QueryPlan.routing`) well defined:

  NONE             full scan (the default; bit-exact by construction).
  ROUTED           scan only the selected segments -- approximate: a true
                   top-k member in a skipped segment is simply lost.
  ROUTED_VERIFIED  scan the selected segments, then compare the result's
                   k-th count (the selection threshold) against the skipped
                   segments' upper bounds; if any skipped segment could still
                   contribute (UB >= threshold -- `>=` because a tied count
                   with a smaller id displaces the k-th slot under the
                   (count desc, id asc) order), fall back to the full scan.
                   Bit-for-bit identical to NONE on every engine x method
                   (tests/test_routing.py).

Per-engine bounds (all computed on the canonical WIDE arrays -- summaries are
built from the prepared array *before* packing, like `build_stats`):

  EQ / TANIMOTO   counts are per-column bucket collisions: UB = number of
                  query columns whose bucket is occupied anywhere in the
                  segment's column (occupancy sketch of `OCC_BUCKETS` bits
                  per column, values hashed by modulo -- collisions only
                  over-count, never under-count).
  RANGE           count = #attributes whose [lo, hi] contains the value:
                  UB = #attributes whose query interval overlaps the
                  segment's per-column [min, max] interval.
  MINSUM          sum_j min(d_j, q_j) <= sum_j min(col_max_j, q_j).
  IP              sum_j d_j*q_j <= sum_j max(col_max_j*q_j, col_min_j*q_j).
  COSINE          sign agreements: UB = #columns whose per-column sign range
                  contains the query sign (exact on the {-1,+1} domain).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Optional, Sequence

import numpy as np

from repro.core.types import Engine

# Bucket-occupancy sketch width for the collision engines (EQ/TANIMOTO).
# Values hash by modulo; a collision marks an extra bucket occupied, which
# can only raise the bound -- soundness never depends on this constant.
OCC_BUCKETS = 2048

# Engines whose counts are per-column bucket collisions (occupancy sketch).
_BUCKETED = (Engine.EQ, Engine.TANIMOTO)


class Routing(str, enum.Enum):
    """Routing mode of a planned search (see module docstring)."""

    NONE = "none"                        # full scan, bit-exact
    ROUTED = "routed"                    # prune, approximate
    ROUTED_VERIFIED = "routed_verified"  # prune + threshold-verify + fallback


@dataclasses.dataclass(frozen=True)
class SegmentSummary:
    """Compact per-segment routing summary, built once at seal time.

    All arrays are host-side numpy: the router runs on the host before any
    device program is dispatched (that is the whole point -- skipped segments
    never touch the device)."""

    engine: Engine
    n_rows: int
    col_min: np.ndarray                  # [width] float64, per-column min
    col_max: np.ndarray                  # [width] float64, per-column max
    centroid: np.ndarray                 # [width] float64, column means
    occupancy: Optional[np.ndarray] = None  # [width, OCC_BUCKETS] bool


def summarize(engine: Engine | str, wide_data) -> SegmentSummary:
    """Summarise one segment's *prepared WIDE* array (call before pack_data,
    never on a packed array -- a packed width is words/bytes, not columns)."""
    engine = Engine(engine)
    arr = np.asarray(wide_data)
    if arr.ndim != 2 or arr.shape[0] < 1:
        raise ValueError(f"summarize needs a non-empty [N, width] array, "
                         f"got shape {arr.shape}")
    occ = None
    if engine in _BUCKETED:
        width = arr.shape[1]
        occ = np.zeros((width, OCC_BUCKETS), dtype=bool)
        cols = np.broadcast_to(np.arange(width)[None, :], arr.shape)
        occ[cols.ravel(), np.mod(arr.astype(np.int64), OCC_BUCKETS).ravel()] = True
    vals = arr.astype(np.float64)
    return SegmentSummary(
        engine=engine,
        n_rows=int(arr.shape[0]),
        col_min=vals.min(axis=0),
        col_max=vals.max(axis=0),
        centroid=vals.mean(axis=0),
        occupancy=occ,
    )


def merge_summaries(a: SegmentSummary, b: SegmentSummary) -> SegmentSummary:
    """Summary of the concatenation of two segments (compaction): bounds
    widen elementwise, occupancies OR, centroids merge row-weighted.  The
    merged bound is >= each source bound, so it stays a sound upper bound."""
    if a.engine is not b.engine:
        raise ValueError(f"cannot merge summaries of engines "
                         f"{a.engine.value!r} and {b.engine.value!r}")
    if a.col_min.shape != b.col_min.shape:
        raise ValueError(f"cannot merge summaries of widths "
                         f"{a.col_min.shape} and {b.col_min.shape}")
    rows = a.n_rows + b.n_rows
    return SegmentSummary(
        engine=a.engine,
        n_rows=rows,
        col_min=np.minimum(a.col_min, b.col_min),
        col_max=np.maximum(a.col_max, b.col_max),
        centroid=(a.centroid * a.n_rows + b.centroid * b.n_rows) / rows,
        occupancy=None if a.occupancy is None else (a.occupancy | b.occupancy),
    )


def _query_matrix(engine: Engine, queries: Any) -> np.ndarray:
    """Canonical WIDE queries -> one [Q, width] float64 point matrix (RANGE
    queries collapse to their interval midpoints -- centroid affinity only)."""
    if engine is Engine.RANGE:
        lo, hi = queries
        return (np.asarray(lo, dtype=np.float64)
                + np.asarray(hi, dtype=np.float64)) / 2.0
    return np.asarray(queries, dtype=np.float64)


def upper_bound(summary: SegmentSummary, queries: Any) -> np.ndarray:
    """Per-query upper bound on the match count any row of this segment can
    reach: float64 [Q].  Sound for every registered engine (see module
    docstring for the per-engine derivations)."""
    eng = summary.engine
    if eng in _BUCKETED:
        q = np.asarray(queries)
        if summary.occupancy is None:
            raise ValueError(f"summary for engine {eng.value!r} carries no "
                             f"occupancy sketch (merged from a foreign one?)")
        cols = np.arange(q.shape[1])
        hit = summary.occupancy[cols[None, :],
                                np.mod(q.astype(np.int64), OCC_BUCKETS)]
        return hit.sum(axis=1).astype(np.float64)
    if eng is Engine.RANGE:
        lo = np.asarray(queries[0], dtype=np.float64)
        hi = np.asarray(queries[1], dtype=np.float64)
        overlap = (lo <= summary.col_max[None, :]) & (hi >= summary.col_min[None, :])
        return overlap.sum(axis=1).astype(np.float64)
    q = np.asarray(queries, dtype=np.float64)
    if eng is Engine.MINSUM:
        return np.minimum(q, summary.col_max[None, :]).sum(axis=1)
    if eng is Engine.IP:
        return np.maximum(q * summary.col_max[None, :],
                          q * summary.col_min[None, :]).sum(axis=1)
    if eng is Engine.COSINE:
        inside = (q >= summary.col_min[None, :]) & (q <= summary.col_max[None, :])
        return inside.sum(axis=1).astype(np.float64)
    raise ValueError(f"no routing bound registered for engine {eng.value!r}")


@dataclasses.dataclass
class Router:
    """Scores query signatures against all segment summaries and picks the
    segments that can contain the top-k.  Built by `SegmentedIndex.router()`;
    consumed by the routed executors in core/plan.py."""

    engine: Engine
    summaries: list[SegmentSummary]

    def __post_init__(self):
        self.engine = Engine(self.engine)
        if not self.summaries:
            raise ValueError("Router needs at least one segment summary")
        for s in self.summaries:
            if s.engine is not self.engine:
                raise ValueError(f"summary engine {s.engine.value!r} != "
                                 f"router engine {self.engine.value!r}")

    @property
    def n_segments(self) -> int:
        return len(self.summaries)

    @property
    def part_rows(self) -> tuple[int, ...]:
        return tuple(s.n_rows for s in self.summaries)

    def default_nprobe(self) -> int:
        """IVF-style default probe width: ~sqrt(#segments)."""
        return max(1, math.isqrt(self.n_segments - 1) + 1)

    def upper_bounds(self, queries: Any) -> np.ndarray:
        """float64 [Q, S]: per-(query, segment) count upper bounds."""
        return np.stack([upper_bound(s, queries) for s in self.summaries],
                        axis=1)

    def select(self, queries: Any, nprobe: Optional[int] = None,
               ubs: Optional[np.ndarray] = None,
               ) -> tuple[np.ndarray, np.ndarray]:
        """(segment mask bool [S], upper bounds float64 [Q, S]).

        Each query ranks segments by (upper bound, centroid affinity) -- the
        affinity is a strict sub-unit tiebreak, so it reorders only segments
        whose integer bounds tie -- and keeps its top `nprobe`; the mask is
        the union over the query batch (the host loop runs the whole batch
        against every scanned part)."""
        if ubs is None:
            ubs = self.upper_bounds(queries)
        nprobe = self.default_nprobe() if nprobe is None else int(nprobe)
        if nprobe < 1:
            raise ValueError(f"nprobe must be >= 1, got {nprobe}")
        nprobe = min(nprobe, self.n_segments)
        q = _query_matrix(self.engine, queries)
        # affinity in (0, 0.5]: closer centroid wins equal-bound ties
        cent = np.stack([s.centroid for s in self.summaries], axis=0)  # [S, w]
        dist = np.sqrt(((q[:, None, :] - cent[None, :, :]) ** 2).sum(axis=2))
        score = ubs + 1.0 / (2.0 + dist)
        top = np.argsort(-score, axis=1, kind="stable")[:, :nprobe]
        mask = np.zeros(self.n_segments, dtype=bool)
        mask[np.unique(top)] = True
        return mask, ubs


# ---------------------------------------------------------------------------
# Shard-mask helpers for the DISTRIBUTED layout (segments -> mesh shards)
# ---------------------------------------------------------------------------

def shard_mask(part_rows: Sequence[int], segment_mask: np.ndarray,
               n_local: int, n_shards: int) -> np.ndarray:
    """bool [n_shards]: a shard is active iff it overlaps any routed segment
    (segments concatenate in global-id order; each shard holds `n_local`
    consecutive rows).  The padded tail past the last segment belongs to no
    segment and activates nothing."""
    n_local = max(int(n_local), 1)
    active = np.zeros(int(n_shards), dtype=bool)
    offset = 0
    for keep, rows in zip(np.asarray(segment_mask), part_rows):
        if keep:
            active[offset // n_local:(offset + rows - 1) // n_local + 1] = True
        offset += rows
    return active


def segments_needing_verify(part_rows: Sequence[int], shard_active: np.ndarray,
                            n_local: int) -> np.ndarray:
    """bool [S]: segments with ANY overlapping inactive shard -- the ones a
    ROUTED_VERIFIED distributed search must check the threshold against.
    (A segment overlapping only active shards was fully scanned -- possibly
    as a bonus rider on a routed neighbour's shard -- and needs no verify.)"""
    n_local = max(int(n_local), 1)
    shard_active = np.asarray(shard_active).astype(bool)
    out = np.zeros(len(part_rows), dtype=bool)
    offset = 0
    for i, rows in enumerate(part_rows):
        out[i] = not shard_active[offset // n_local:
                                  (offset + rows - 1) // n_local + 1].all()
        offset += rows
    return out
