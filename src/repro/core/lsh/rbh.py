"""Random Binning Hashing (RBH) for the Laplacian kernel (paper section IV-A3).

Rahimi & Recht random features: for a separable kernel k(p,q) = prod_d k1(|p_d - q_d|)
whose per-dim kernel k1 has p(g) = g * k1''(g) a valid density on g >= 0, impose a
randomly shifted grid with pitch g ~ p(g) and shift u ~ U[0, g] per dimension:

    h(p) = [ floor((p_1 - u_1)/g_1), ..., floor((p_d - u_d)/g_d) ]      (paper Eqn 2)

Then Pr[h(p) = h(q)] = k(p, q).  For the Laplacian kernel
k(p,q) = exp(-||p-q||_1 / sigma), the pitch density per dimension is
p(g) = (g / sigma^2) exp(-g / sigma), i.e. Gamma(shape=2, scale=sigma).

The signature is a d-dimensional integer vector -- a huge space -- so GENIE
re-hashes it into [0, D) with r(.) (rehash.rehash_vector).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.lsh import rehash as _rehash


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RBHParams:
    g: jnp.ndarray            # [m, d] grid pitches ~ Gamma(2, sigma)
    u: jnp.ndarray            # [m, d] shifts ~ U[0, g]
    dim_seeds: jnp.ndarray    # [m, d] uint32 per-coordinate combine seeds
    sigma: float = dataclasses.field(metadata=dict(static=True))
    n_buckets: int = dataclasses.field(metadata=dict(static=True))


def make(key, d: int, m: int, sigma: float, n_buckets: int = 8192) -> RBHParams:
    kg, ku, ks = jax.random.split(key, 3)
    # Gamma(shape=2, scale=sigma): sum of two Exp(scale=sigma) draws.
    g = sigma * (jax.random.gamma(kg, 2.0, (m, d), dtype=jnp.float32))
    u = jax.random.uniform(ku, (m, d), dtype=jnp.float32) * g
    dim_seeds = jax.random.randint(ks, (m, d), 0, 2**31 - 1, dtype=jnp.int32).astype(jnp.uint32)
    return RBHParams(g=g, u=u, dim_seeds=dim_seeds, sigma=sigma, n_buckets=n_buckets)


def raw_hash(params: RBHParams, x: jnp.ndarray) -> jnp.ndarray:
    """Grid coordinates int32 [..., m, d]."""
    # x: [..., d];  g,u: [m, d]
    x = x[..., None, :]  # [..., 1, d]
    return jnp.floor((x - params.u) / params.g).astype(jnp.int32)


def hash_points(params: RBHParams, x: jnp.ndarray) -> jnp.ndarray:
    """Signatures int32 [..., m] in [0, n_buckets) (vector signature re-hashed)."""
    cells = raw_hash(params, x)  # [..., m, d]
    m, d = params.g.shape
    # rehash_vector folds the d grid coordinates of each function; vmap over m.
    def fold_one(cells_m, seeds_m):
        return _rehash.rehash_vector(cells_m, seeds_m, params.n_buckets)

    # cells: [..., m, d] -> move m first for vmap
    cells_mf = jnp.moveaxis(cells, -2, 0)  # [m, ..., d]
    folded = jax.vmap(fold_one)(cells_mf, params.dim_seeds)  # [m, ...]
    return jnp.moveaxis(folded, 0, -1)  # [..., m]


def kernel(x: jnp.ndarray, y: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """Laplacian kernel k(p,q) = exp(-||p-q||_1 / sigma) == expected collision prob."""
    return jnp.exp(-jnp.sum(jnp.abs(x - y), axis=-1) / sigma)


def median_heuristic_sigma(points: jnp.ndarray, key, n_pairs: int = 2048) -> float:
    """Kernel-width heuristic used in the paper (Jaakkola et al.): mean pairwise
    l1 distance over a random sample."""
    n = points.shape[0]
    ki, kj = jax.random.split(key)
    i = jax.random.randint(ki, (n_pairs,), 0, n)
    j = jax.random.randint(kj, (n_pairs,), 0, n)
    d = jnp.sum(jnp.abs(points[i] - points[j]), axis=-1)
    return float(jnp.mean(d))
