"""SimHash (signed random projection) LSH for angular / cosine similarity.

Charikar (paper ref [5]): h_v(p) = sign(v . p) with v ~ N(0, I) satisfies

    Pr[h(p) = h(q)] = 1 - theta(p, q) / pi

which is a valid GENIE LSH family (Eqn 1) under the angular similarity
sim(p,q) = 1 - theta/pi.  Signatures are single bits, so the match-count
domain is exactly m and no re-hashing is needed (D = 2; the 1/D re-hash
collision term of Theorem 4.1 does not apply because r is the identity).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimHashParams:
    v: jnp.ndarray  # [m, d]


def make(key, d: int, m: int) -> SimHashParams:
    return SimHashParams(v=jax.random.normal(key, (m, d), dtype=jnp.float32))


def hash_points(params: SimHashParams, x: jnp.ndarray) -> jnp.ndarray:
    proj = jnp.einsum("...d,md->...m", x.astype(jnp.float32), params.v)
    return (proj >= 0).astype(jnp.int32)


def mle_cosine(count, m: int):
    """Cosine estimate from a sign-agreement count (the COSINE engine's MLE).

    c agreements out of m bits give Pr[agree] = 1 - theta/pi (Charikar), so
    theta_hat = pi * (1 - c/m) and cos_hat = cos(theta_hat).  Host-side, like
    tau_ann.mle_similarity (Eqn 7).
    """
    frac = np.clip(np.asarray(count, dtype=np.float64) / float(m), 0.0, 1.0)
    return np.cos(math.pi * (1.0 - frac))


def similarity(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Angular similarity 1 - theta/pi."""
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
    cos = jnp.clip(jnp.sum(xn * yn, axis=-1), -1.0, 1.0)
    return 1.0 - jnp.arccos(cos) / math.pi
