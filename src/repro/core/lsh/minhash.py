"""MinHash LSH for Jaccard similarity over sets (paper section II-B1: "Jaccard
kernel for sets").

h_i(S) = min_{e in S} pi_i(e) with pi_i a random permutation (approximated by
the Murmur fmix32 bijection keyed per function).  Pr[h(S) = h(T)] = J(S, T),
which satisfies GENIE's LSH definition (Eqn 1) exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.lsh import rehash as _rehash


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MinHashParams:
    seeds: jnp.ndarray        # [m] uint32 per-function permutation seeds
    rehash_seeds: jnp.ndarray  # [m] uint32 seeds for the bucket projection
    n_buckets: int = dataclasses.field(metadata=dict(static=True))


def make(key, m: int, n_buckets: int = 8192, d: int | None = None) -> MinHashParams:
    """`d` is accepted (and ignored) so the scheme registry's uniform
    make_params(key, d=..., m=..., ...) call works -- minhash is
    dimension-free (permutations act on element ids, not coordinates)."""
    k1, k2 = jax.random.split(key)
    return MinHashParams(
        seeds=_rehash.make_seeds(k1, m),
        rehash_seeds=_rehash.make_seeds(k2, m),
        n_buckets=n_buckets,
    )


def hash_sets(params: MinHashParams, elements: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """MinHash signatures for padded element-id sets.

    elements: int32 [..., L]  element ids (padded rows allowed).
    valid:    bool  [..., L]  mask of real elements.
    returns:  int32 [..., m]  signatures in [0, n_buckets).
    """
    e = elements.astype(jnp.uint32)[..., None, :]          # [..., 1, L]
    seeds = params.seeds[:, None]                          # [m, 1]
    perm = _rehash.fmix32(e ^ seeds)                       # [..., m, L]
    big = jnp.uint32(0xFFFFFFFF)
    perm = jnp.where(valid[..., None, :], perm, big)
    mins = jnp.min(perm, axis=-1)                          # [..., m]
    return _rehash.rehash(mins.astype(jnp.int32), params.rehash_seeds, params.n_buckets)


def hash_points(params: MinHashParams, x: jnp.ndarray) -> jnp.ndarray:
    """MinHash dense vectors via their positive-support feature set.

    A vector x is read as the set {i : x_i > 0} (binarised feature support --
    the sparse ultra-high-dimensional regime FLASH targets), then minhashed
    with `hash_sets`.  Gives the scheme registry the uniform
    hash_points(params, x [..., d]) -> sigs [..., m] signature.
    """
    x = jnp.asarray(x)
    d = x.shape[-1]
    elems = jnp.broadcast_to(jnp.arange(d, dtype=jnp.int32), x.shape)
    return hash_sets(params, elems, x > 0)


def jaccard(a_elems, a_valid, b_elems, b_valid) -> float:
    """Host-side exact Jaccard for validation."""
    sa = set(int(x) for x, v in zip(a_elems, a_valid) if v)
    sb = set(int(x) for x, v in zip(b_elems, b_valid) if v)
    if not sa and not sb:
        return 1.0
    return len(sa & sb) / len(sa | sb)
