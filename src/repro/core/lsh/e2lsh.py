"""E2LSH: p-stable locality sensitive hashing (paper Eqn 10/11, Datar et al.).

h(q) = floor((a . q + b) / w) with `a` drawn from a p-stable distribution
(Gaussian for l2, Cauchy for l1) and b ~ U[0, w).

The collision probability (paper Eqn 11)

    psi_p(delta) = Pr[h(p) = h(q)]
                 = int_0^w (1/delta) phi_p(t/delta) (1 - t/w) dt

is strictly monotonically decreasing in delta = ||p - q||_p, so it defines the
similarity measure sim_lp (Eqn 12) under which GENIE performs tau-ANN search.
Closed forms are implemented below for l1 and l2.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.lsh import rehash as _rehash


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class E2LSHParams:
    a: jnp.ndarray          # [m, d] p-stable projection vectors
    b: jnp.ndarray          # [m]    uniform shifts in [0, w)
    seeds: jnp.ndarray      # [m]    uint32 rehash seeds
    w: float = dataclasses.field(metadata=dict(static=True))
    p: int = dataclasses.field(metadata=dict(static=True))
    n_buckets: int = dataclasses.field(metadata=dict(static=True))


def make(key, d: int, m: int, w: float, p: int = 2, n_buckets: int = 8192) -> E2LSHParams:
    """Create m independent p-stable LSH functions for d-dim points."""
    ka, kb, ks = jax.random.split(key, 3)
    if p == 2:
        a = jax.random.normal(ka, (m, d), dtype=jnp.float32)
    elif p == 1:
        a = jax.random.cauchy(ka, (m, d), dtype=jnp.float32)
    else:
        raise ValueError(f"p-stable sampling implemented for p in (1, 2), got {p}")
    b = jax.random.uniform(kb, (m,), minval=0.0, maxval=w, dtype=jnp.float32)
    return E2LSHParams(a=a, b=b, seeds=_rehash.make_seeds(ks, m), w=w, p=p, n_buckets=n_buckets)


def raw_hash(params: E2LSHParams, x: jnp.ndarray) -> jnp.ndarray:
    """floor((a.x + b)/w) -> int32 [..., m] (pre-rehash bucket coordinates)."""
    proj = jnp.einsum("...d,md->...m", x.astype(jnp.float32), params.a)
    return jnp.floor((proj + params.b) / params.w).astype(jnp.int32)


def hash_points(params: E2LSHParams, x: jnp.ndarray) -> jnp.ndarray:
    """Full GENIE transform: signatures int32 [..., m] in [0, n_buckets)."""
    return _rehash.rehash(raw_hash(params, x), params.seeds, params.n_buckets)


# ---------------------------------------------------------------------------
# Collision probability psi_p (paper Eqn 11) -- closed forms.
# ---------------------------------------------------------------------------

def collision_prob_l2(dist, w: float):
    """psi_2(delta) for Gaussian projections (Datar et al. Eqn in section 3.2)."""
    dist = jnp.maximum(jnp.asarray(dist, dtype=jnp.float32), 1e-12)
    r = w / dist
    # 1 - 2*Phi(-r) - (2/(sqrt(2 pi) r)) * (1 - exp(-r^2/2))
    phi_neg = 0.5 * (1.0 + jax.scipy.special.erf(-r / math.sqrt(2.0)))
    return 1.0 - 2.0 * phi_neg - (2.0 / (math.sqrt(2.0 * math.pi) * r)) * (
        1.0 - jnp.exp(-(r**2) / 2.0)
    )


def collision_prob_l1(dist, w: float):
    """psi_1(delta) for Cauchy projections."""
    dist = jnp.maximum(jnp.asarray(dist, dtype=jnp.float32), 1e-12)
    r = w / dist
    return (2.0 * jnp.arctan(r) / math.pi) - (1.0 / (math.pi * r)) * jnp.log1p(r**2)


def collision_prob(dist, w: float, p: int):
    if p == 2:
        return collision_prob_l2(dist, w)
    if p == 1:
        return collision_prob_l1(dist, w)
    raise ValueError(f"unsupported p={p}")


def similarity(params: E2LSHParams, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """sim_lp(p, q) = psi_p(||p-q||_p)  (paper Eqn 12)."""
    if params.p == 2:
        d = jnp.linalg.norm(x - y, axis=-1)
    else:
        d = jnp.sum(jnp.abs(x - y), axis=-1)
    return collision_prob(d, params.w, params.p)
