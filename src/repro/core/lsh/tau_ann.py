"""tau-ANN theory (paper section IV-B).

Definition 4.1 (tau-ANN): return p with |sim(p,q) - sim(p*,q)| <= tau w.h.p.

Theorem 4.1 gives the conservative bound  m = ceil(2 ln(3/delta) / eps^2)
hash functions for |MC/m - sim| < eps + 1/D  w.p. >= 1 - delta.

Eqn 9 gives the practical (data-independent) bound: for true similarity s the
count c ~ Binomial(m, s), so

    Pr[|c/m - s| <= eps] = sum_{c=floor((s-eps)m)}^{ceil((s+eps)m)} C(m,c) s^c (1-s)^(m-c)

and the required m for a given (eps, delta) is the max over s of the minimal m
meeting the constraint.  The paper (Fig 8) reports m = 237 at eps = delta = 0.06
with the worst case at s = 0.5; `required_m` reproduces this.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np
from scipy import stats


def m_theorem41(eps: float, delta: float) -> int:
    """Conservative bound of Theorem 4.1: m = ceil(2 ln(3/delta) / eps^2)."""
    return int(math.ceil(2.0 * math.log(3.0 / delta) / (eps * eps)))


def prob_within(m: int, s: float, eps: float) -> float:
    """Pr[|c/m - s| <= eps] with c ~ Binomial(m, s)  (paper Eqn 8/9).

    Note: Eqn 9 prints the summation limits as floor((s-eps)m)..ceil((s+eps)m),
    but the event |c/m - s| <= eps corresponds to ceil((s-eps)m) <= c <=
    floor((s+eps)m); the printed convention admits c outside the eps-window and
    makes m=1 trivially "sufficient".  We use the exact event (and reproduce the
    paper's m = 237 at eps = delta = 0.06, worst case s = 0.5 -- Fig 8).
    """
    lo = int(math.ceil((s - eps) * m))
    hi = int(math.floor((s + eps) * m))
    lo = max(lo, 0)
    hi = min(hi, m)
    if lo > hi:
        return 0.0
    # sum_{c=lo}^{hi} Binom(m, s).pmf(c) = cdf(hi) - cdf(lo - 1)
    b = stats.binom(m, s)
    return float(b.cdf(hi) - (b.cdf(lo - 1) if lo > 0 else 0.0))


def min_m_for_similarity(s: float, eps: float, delta: float, m_max: int = 4096) -> int:
    """Minimal m such that Pr[|c/m - s| <= eps] >= 1 - delta (binary search is
    invalid -- the binomial tail is not monotone in m due to the floor/ceil
    window -- so scan linearly)."""
    for m in range(1, m_max + 1):
        if prob_within(m, s, eps) >= 1.0 - delta:
            return m
    return m_max


@lru_cache(maxsize=None)
def required_m(eps: float, delta: float, s_grid: int = 101, m_max: int = 4096) -> int:
    """Data-independent practical m: max over similarity values of min_m (Fig 8)."""
    best = 0
    for i in range(1, s_grid - 1):
        s = i / (s_grid - 1)
        best = max(best, min_m_for_similarity(s, eps, delta, m_max))
    return best


def fig8_curve(eps: float = 0.06, delta: float = 0.06, s_grid: int = 101, m_max: int = 4096):
    """(s, min m) pairs reproducing paper Fig 8."""
    ss = [i / (s_grid - 1) for i in range(1, s_grid - 1)]
    return np.array(ss), np.array([min_m_for_similarity(s, eps, delta, m_max) for s in ss])


def mle_similarity(count, m: int):
    """MLE estimate s_hat = c/m (paper Eqn 7)."""
    return np.asarray(count, dtype=np.float64) / float(m)
