"""LSH transforms + the scheme registry.

Mirrors the MatchModel registry (core/engines.py) for the *transformation*
side of GENIE's genericity claim: each LSH family is one `LshScheme`
descriptor bundling parameter construction and point hashing behind a
uniform interface, so serving code (serve/retrieval.py) and examples select
schemes by name instead of string-keyed if-chains.

    scheme = lsh.get_scheme("e2lsh")
    params = scheme.make_params(key, d=32, m=237, w=4.0, n_buckets=8192)
    sigs = scheme.hash_points(params, x)

`make_params` filters its keyword options to what the scheme accepts (e.g.
`w` for e2lsh, `sigma` for rbh, nothing for simhash), so one call site can
carry the union of options.  Register a new family with `register_scheme`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core.lsh import e2lsh, minhash, rbh, rehash, simhash, tau_ann  # noqa: F401
from repro.core.types import Engine


@dataclasses.dataclass(frozen=True)
class LshScheme:
    """Descriptor for one LSH family (paper section IV).

    `engine` names the MatchModel that consumes this family's signatures
    (the transform <-> measure pairing: bucketed schemes count collisions
    with EQ, minhash sketches with TANIMOTO, simhash bits with COSINE), and
    `mle` inverts a match count into the similarity the family estimates.
    Serving (serve/retrieval.py) resolves both by scheme name, so selecting a
    scheme selects the whole engine stack.
    """

    name: str
    description: str
    make: Callable[..., Any]                 # (key, *, d, m, **options) -> params
    hash_points: Callable[[Any, Any], Any]   # (params, x [..., d]) -> sigs [..., m]
    option_names: tuple[str, ...] = ()       # keyword options `make` accepts
    engine: Engine = Engine.EQ               # match engine paired with the sigs
    # (counts, m) -> similarity estimate; default is the tau-ANN MLE c/m (Eqn 7)
    mle: Callable[[Any, int], Any] = tau_ann.mle_similarity

    def make_params(self, key, *, d: int, m: int, **options) -> Any:
        """Build scheme parameters, keeping only the options this family uses."""
        kept = {k: v for k, v in options.items() if k in self.option_names}
        return self.make(key, d=d, m=m, **kept)


_SCHEMES: dict[str, LshScheme] = {}


def register_scheme(scheme: LshScheme) -> LshScheme:
    _SCHEMES[scheme.name] = scheme
    return scheme


def get_scheme(name: str | LshScheme) -> LshScheme:
    if isinstance(name, LshScheme):
        return name
    try:
        return _SCHEMES[name]
    except KeyError:
        raise KeyError(
            f"unknown LSH scheme {name!r}; known: {sorted(_SCHEMES)}"
        ) from None


def scheme_names() -> tuple[str, ...]:
    return tuple(sorted(_SCHEMES))


register_scheme(LshScheme(
    name="e2lsh",
    description="p-stable LSH for l1/l2 distance (paper Eqn 10/11)",
    make=e2lsh.make,
    hash_points=e2lsh.hash_points,
    option_names=("w", "p", "n_buckets"),
))

register_scheme(LshScheme(
    name="rbh",
    description="random binning hashing for the Laplacian kernel (section IV-A3)",
    make=rbh.make,
    hash_points=rbh.hash_points,
    option_names=("sigma", "n_buckets"),
))

register_scheme(LshScheme(
    name="simhash",
    description="signed random projection for angular similarity (Charikar)",
    make=simhash.make,
    hash_points=simhash.hash_points,
    option_names=(),
    engine=Engine.COSINE,                 # bits become +-1 signs on the MXU
    mle=simhash.mle_cosine,
))

register_scheme(LshScheme(
    name="minhash",
    description="minhash over positive-support feature sets for Jaccard (FLASH)",
    make=minhash.make,
    hash_points=minhash.hash_points,
    option_names=("n_buckets",),
    engine=Engine.TANIMOTO,               # sketch collisions count Jaccard
))
