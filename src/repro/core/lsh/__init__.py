from repro.core.lsh import e2lsh, minhash, rbh, rehash, simhash, tau_ann  # noqa: F401
