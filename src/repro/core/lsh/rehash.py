"""Re-hashing mechanism r(.) of GENIE (paper section IV-A2, Fig 7).

LSH signatures can live in a huge (even unbounded) space -- e.g. Random Binning
Hashing emits one integer grid coordinate per input dimension.  GENIE re-hashes
each signature into a small domain [0, D) with a random projection function
r(.).  The paper uses MurmurHash3; we implement the Murmur3 32-bit finalizer
(fmix32) plus seed mixing in pure JAX uint32 arithmetic so the whole transform
runs on device and is deterministic across hosts.
"""
from __future__ import annotations

import jax.numpy as jnp

_C1 = jnp.uint32(0x85EBCA6B)
_C2 = jnp.uint32(0xC2B2AE35)
_GOLDEN = jnp.uint32(0x9E3779B9)


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """MurmurHash3 32-bit finalizer: a bijective avalanche mix on uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_combine(acc: jnp.ndarray, value: jnp.ndarray) -> jnp.ndarray:
    """Combine a hash accumulator with a new value (boost-style)."""
    acc = acc.astype(jnp.uint32)
    value = fmix32(value.astype(jnp.uint32))
    return acc ^ (value + _GOLDEN + (acc << 6) + (acc >> 2))


def rehash(signature: jnp.ndarray, seed: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """r_i(h_i(p)): project integer signatures into [0, n_buckets).

    signature: int array [..., m]  -- one signature per hash function.
    seed:      uint32 [m]          -- independent seed per function (makes the
                                      m projections r_1..r_m independent).
    returns int32 [..., m] in [0, n_buckets).
    """
    mixed = fmix32(signature.astype(jnp.uint32) ^ seed.astype(jnp.uint32))
    return (mixed % jnp.uint32(n_buckets)).astype(jnp.int32)


def rehash_vector(signature_vec: jnp.ndarray, seeds: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """Re-hash a *vector-valued* signature (e.g. RBH's per-dimension grid cell
    vector) into a single bucket id in [0, n_buckets).

    signature_vec: int [..., d]   -- d-dimensional signature of ONE hash function.
    seeds:         uint32 [d]     -- per-coordinate seeds.
    returns int32 [...] in [0, n_buckets).
    """
    acc = jnp.zeros(signature_vec.shape[:-1], dtype=jnp.uint32)
    # Fold coordinates with an order-sensitive combine (vectorised via scan-free
    # reduction: combine(acc, x_d) sequentially over the last axis).
    d = signature_vec.shape[-1]
    for i in range(d):  # d is static and small (data dimensionality)
        acc = hash_combine(acc, signature_vec[..., i].astype(jnp.uint32) ^ seeds[i])
    return (fmix32(acc) % jnp.uint32(n_buckets)).astype(jnp.int32)


def make_seeds(key, m: int) -> jnp.ndarray:
    """Draw m independent uint32 seeds from a JAX PRNG key."""
    import jax

    return jax.random.randint(key, (m,), minval=0, maxval=2**31 - 1, dtype=jnp.int32).astype(
        jnp.uint32
    )
