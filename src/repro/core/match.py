"""Match-count reference semantics (paper Definition 2.1), TPU-native dense
formulations.

Each function computes counts[q, n] = MC(Q_q, O_n) for a query batch against
all objects.  These pure-jnp implementations are the semantics oracles for the
Pallas kernels in repro.kernels and the small-scale fallback path.  They are
not called directly by the index machinery: engine dispatch goes through the
MatchModel registry (core/engines.py), where each engine's descriptor pairs
the reference here with its kernel, query canonicalisation, and build policy.

Memory note: counts are bounded by max_count (m hash functions / #attributes /
#grams) -- the paper's Bitmap-Counter observation (section III-C) -- so an int8
output is lossless whenever max_count <= 127; `as_count_dtype` applies it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def as_count_dtype(counts: jnp.ndarray, max_count: int) -> jnp.ndarray:
    """Bitmap-Counter bit-bounding: store counts in the narrowest safe dtype."""
    if max_count <= 127:
        return counts.astype(jnp.int8)
    if max_count <= 32767:
        return counts.astype(jnp.int16)
    return counts.astype(jnp.int32)


def _pad_axis1(x: jnp.ndarray, chunk: int, value) -> jnp.ndarray:
    m = x.shape[1]
    target = -(-m // chunk) * chunk
    if target == m:
        return x
    return jnp.pad(x, ((0, 0), (0, target - m)), constant_values=value)


def _scan_chunks(d: jnp.ndarray, s: jnp.ndarray, chunk: int, combine) -> jnp.ndarray:
    """counts[q, n] = sum over chunks of combine(d_chunk [N,c], s_chunk [Q,c]).

    A lax.scan over the reduced axis keeps live temps at [Q, N, chunk]
    regardless of m and the HLO compact (padding must be combine-neutral)."""
    q, n = s.shape[0], d.shape[0]
    dc = jnp.moveaxis(d.reshape(n, -1, chunk), 1, 0)    # [nc, N, c]
    sc = jnp.moveaxis(s.reshape(q, -1, chunk), 1, 0)    # [nc, Q, c]

    def step(acc, xs):
        dcc, scc = xs
        return acc + combine(dcc, scc), None

    acc, _ = jax.lax.scan(step, jnp.zeros((q, n), jnp.int32), (dc, sc))
    return acc


def match_eq(data_sigs: jnp.ndarray, query_sigs: jnp.ndarray, chunk: int = 8) -> jnp.ndarray:
    """EQ engine: counts[q, n] = sum_i (data_sigs[n, i] == query_sigs[q, i]).

    data_sigs:  int [N, m], query_sigs: int [Q, m] -> int32 [Q, N].
    Input dtype is preserved (int8 signatures when the rehash domain fits --
    4x less HBM traffic for the dominant stream; EXPERIMENTS.md hillclimb C).
    """
    d = _pad_axis1(data_sigs, chunk, -1)
    s = _pad_axis1(query_sigs, chunk, -2)

    def combine(dcc, scc):
        hit = scc[:, None, :] == dcc[None, :, :]
        return jnp.sum(hit.astype(jnp.int8), axis=-1).astype(jnp.int32)

    return _scan_chunks(d, s, chunk, combine)


def match_range(
    data_vals: jnp.ndarray, q_lo: jnp.ndarray, q_hi: jnp.ndarray, chunk: int = 8
) -> jnp.ndarray:
    """RANGE engine: counts[q, n] = sum_d (q_lo[q,d] <= data_vals[n,d] <= q_hi[q,d]).

    Implements the relational-table match count (paper Example 2.1 / section V-C)
    directly on discretized attribute values -- the inverted index over
    (attribute, value) keywords is semantically this predicate count.
    """
    x = _pad_axis1(data_vals.astype(jnp.int32), chunk, 0)
    lohi = jnp.stack(
        [_pad_axis1(q_lo.astype(jnp.int32), chunk, 1),
         _pad_axis1(q_hi.astype(jnp.int32), chunk, 0)], axis=-1
    ).reshape(q_lo.shape[0], -1)  # interleave lo/hi so _scan_chunks sees one array

    def combine(dcc, scc):
        c = dcc.shape[-1]
        lo = scc[:, 0::2][:, :c]
        hi = scc[:, 1::2][:, :c]
        hit = (dcc[None, :, :] >= lo[:, None, :]) & (dcc[None, :, :] <= hi[:, None, :])
        return jnp.sum(hit.astype(jnp.int8), axis=-1).astype(jnp.int32)

    # lo/hi interleaved doubles the chunk on the query side
    q, n = q_lo.shape[0], x.shape[0]
    dc = jnp.moveaxis(x.reshape(n, -1, chunk), 1, 0)
    sc = jnp.moveaxis(lohi.reshape(q, -1, 2 * chunk), 1, 0)

    def step(acc, xs):
        dcc, scc = xs
        return acc + combine(dcc, scc), None

    acc, _ = jax.lax.scan(step, jnp.zeros((q, n), jnp.int32), (dc, sc))
    return acc


def match_minsum(data_cnt: jnp.ndarray, query_cnt: jnp.ndarray, chunk: int = 8) -> jnp.ndarray:
    """MINSUM engine: counts[q, n] = sum_v min(data_cnt[n,v], query_cnt[q,v]).

    Exactly Lemma 5.1's ordered-n-gram match count when the count vectors are
    per-gram-type multiplicities (bucketised count vectors give an upper bound;
    see sa/ngram.py).
    """
    d = _pad_axis1(data_cnt.astype(jnp.int32), chunk, 0)
    s = _pad_axis1(query_cnt.astype(jnp.int32), chunk, 0)

    def combine(dcc, scc):
        return jnp.sum(jnp.minimum(scc[:, None, :], dcc[None, :, :]), axis=-1)

    return _scan_chunks(d, s, chunk, combine)


def match_tanimoto(data_sigs: jnp.ndarray, query_sigs: jnp.ndarray, chunk: int = 8) -> jnp.ndarray:
    """TANIMOTO engine: counts[q, n] = sum_i (data_sigs[n, i] == query_sigs[q, i])
    over *minhash* signatures.

    Pr[h(S) = h(T)] = J(S, T) for minhash (core/lsh/minhash.py), so the
    collision count c is Binomial(m, J) and J_hat = c/m is the Jaccard MLE --
    the sketch-collision counting at the heart of FLASH (Wang et al.,
    1709.01190).  The arithmetic is the EQ compare; the engines differ in data
    semantics (minhash sketches of sets vs. generic LSH signatures), count
    interpretation, and kernel (kernels/tanimoto_count.py tiles the signature
    axis through the grid for FLASH-scale m).
    """
    return match_eq(data_sigs, query_sigs, chunk=chunk)


def tanimoto_exact(data_cnt: jnp.ndarray, query_cnt: jnp.ndarray, chunk: int = 8) -> jnp.ndarray:
    """Exact (multiset) Tanimoto  sum_v min / sum_v max  -> float32 [Q, N].

    The validation oracle for the TANIMOTO engine: on multiset count vectors
    the engine's minhash-collision estimate J_hat = c/m converges to this
    ratio (binary vectors give exactly set Jaccard).  Not a match-count --
    GENIE counts stay integral; this is the similarity the counts estimate.
    """
    d = _pad_axis1(data_cnt.astype(jnp.int32), chunk, 0)
    s = _pad_axis1(query_cnt.astype(jnp.int32), chunk, 0)

    def combine_min(dcc, scc):
        return jnp.sum(jnp.minimum(scc[:, None, :], dcc[None, :, :]), axis=-1)

    mins = _scan_chunks(d, s, chunk, combine_min)
    # min(a,b) + max(a,b) == a + b, so sum-max follows from row sums -- no
    # second O(Q*N*V) scan.
    maxs = jnp.sum(d, axis=-1)[None, :] + jnp.sum(s, axis=-1)[:, None] - mins
    return mins.astype(jnp.float32) / jnp.maximum(maxs, 1).astype(jnp.float32)


def match_cosine(data_sgn: jnp.ndarray, query_sgn: jnp.ndarray, chunk: int = 8) -> jnp.ndarray:
    """COSINE engine: counts[q, n] = #sign agreements = (V + <s_q, s_n>) // 2.

    data_sgn / query_sgn are sign-quantized vectors in {-1, +1} ([N, V] /
    [Q, V]); the agreement count of simhash bits equals the shifted +-1 inner
    product, which is what the Pallas kernel computes on the MXU
    (kernels/cosine_count.py).  cos(theta) is estimated from the count by the
    simhash angle MLE cos(pi * (1 - c/V)) (core/lsh/simhash.py).  V + dot is
    even for genuine +-1 rows, so the halving is exact; zero pad rows floor.
    """
    v = int(data_sgn.shape[1])
    d = _pad_axis1(data_sgn.astype(jnp.int32), chunk, 0)
    s = _pad_axis1(query_sgn.astype(jnp.int32), chunk, 0)

    def combine(dcc, scc):
        return jnp.sum(scc[:, None, :] * dcc[None, :, :], axis=-1)

    dot = _scan_chunks(d, s, chunk, combine)
    return (v + dot) // 2


def match_ip(data_bin: jnp.ndarray, query_bin: jnp.ndarray) -> jnp.ndarray:
    """IP engine: counts = query_bin @ data_bin^T (binary vectors; MXU matmul).

    The short-document model of section V-B: MC == inner product of binary
    word vectors.
    """
    acc = jnp.einsum(
        "qv,nv->qn",
        query_bin.astype(jnp.float32),
        data_bin.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return jnp.round(acc).astype(jnp.int32)
