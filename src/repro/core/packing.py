"""Bit/byte-packed signature formats (FLASH's core trick, Wang et al.
1709.01190; compact codes as the billion-scale prerequisite, Johnson et al.
1702.08734).

The WIDE layouts spend far more bits than the information they carry: COSINE
stores one +-1 *sign* (1 bit) per int8 element -- and the kernel upcasts it
to bf16 on the way to the MXU (16 bits moved per bit of signal) -- while
TANIMOTO stores a minhash bucket id (< 2^8 for practical bucket counts) per
int32 element.  This module defines the PACKED formats and their pure-jnp
match references; the Pallas hot paths live in kernels/packed_cosine.py and
kernels/packed_tanimoto.py.

COSINE / sign vectors -> uint32 bitfields
    word w, bit b of a packed row holds (sign[32*w + b] > 0); rows narrow
    from V bytes (int8) to ceil(V/32)*4 bytes.  The sign-agreement count is
    recovered by XOR + popcount:

        agreements = 32*W - popcount(q_words XOR d_words)

    with the *data* tail bits (past V in the last word) packed as 0 and the
    *query* tail bits packed as 1, so every tail bit is a guaranteed
    disagreement and the identity needs no knowledge of V -- the packed
    match keeps the canonical ``fn(data, queries) -> counts`` signature.

TANIMOTO / minhash sketches -> uint8 bucket ids
    bucket ids narrow from 4 bytes to 1 when the rehash domain fits a byte;
    the match is the same equality compare on byte lanes.  Values 254/255
    are reserved as query/data pad sentinels (kernels/ops.py), so packing
    requires bucket ids <= PACKED_BUCKET_MAX.

Both packed matches are bit-for-bit identical to their WIDE references --
the conformance legs in tests/test_engine_matrix.py and tests/test_plan.py
pin that across every layout x selection method.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import match as _match

WORD_BITS = 32
# uint8 sentinels reserved by the packed-TANIMOTO kernel wrapper: 255 fills
# padded data slots, 254 padded query slots (distinct so pads never collide).
PACKED_BUCKET_PAD_DATA = 255
PACKED_BUCKET_PAD_QUERY = 254
PACKED_BUCKET_MAX = 253


def packed_words(v: int) -> int:
    """Words per packed sign row for a logical dimensionality of v."""
    return -(-int(v) // WORD_BITS)


def _pack_bits(bits: jnp.ndarray, tail_bit: bool) -> jnp.ndarray:
    """bool [N, V] -> int32 words [N, ceil(V/32)] (little-endian bit order),
    tail slots past V filled with `tail_bit`."""
    n, v = bits.shape
    w = packed_words(v)
    pad = w * WORD_BITS - v
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)), constant_values=tail_bit)
    lanes = bits.reshape(n, w, WORD_BITS).astype(jnp.uint32)
    weights = jnp.left_shift(jnp.uint32(1),
                             jnp.arange(WORD_BITS, dtype=jnp.uint32))
    words = jnp.sum(lanes * weights, axis=-1)          # uint32 [N, W]
    # int32 storage (bit-identical reinterpret): signed words keep jnp.pad /
    # Pallas block plumbing on the well-trodden int path
    return jax.lax.bitcast_convert_type(words, jnp.int32)


def pack_signs_data(sgn: jnp.ndarray) -> jnp.ndarray:
    """Sign-quantized data {-1,+1} [N, V] -> packed int32 words [N, W];
    tail bits 0 (they pair with query tail bits 1 -> always a disagreement)."""
    return _pack_bits(jnp.asarray(sgn) > 0, tail_bit=False)


def pack_signs_queries(sgn: jnp.ndarray) -> jnp.ndarray:
    """Sign-quantized queries {-1,+1} [Q, V] -> packed int32 words [Q, W];
    tail bits 1 (see pack_signs_data)."""
    return _pack_bits(jnp.asarray(sgn) > 0, tail_bit=True)


def unpack_signs(words: jnp.ndarray, v: int) -> jnp.ndarray:
    """Packed int32 words [N, W] -> signs {-1,+1} int8 [N, v] (testing aid)."""
    u = jax.lax.bitcast_convert_type(words, jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (u[..., None] >> shifts) & jnp.uint32(1)     # [N, W, 32]
    flat = bits.reshape(words.shape[0], -1)[:, :v]
    return jnp.where(flat == 1, 1, -1).astype(jnp.int8)


def packed_cosine_match(data_words: jnp.ndarray,
                        query_words: jnp.ndarray) -> jnp.ndarray:
    """counts[q, n] = 32*W - popcount(q_words ^ d_words): the pure-jnp
    reference for the packed COSINE layout (kernels/packed_cosine.py is the
    Pallas hot path).  Exact -- not an estimate -- versus match_cosine on
    the unpacked signs."""
    d = jnp.asarray(data_words, dtype=jnp.int32)
    s = jnp.asarray(query_words, dtype=jnp.int32)
    bits_total = WORD_BITS * d.shape[1]

    def combine(dcc, scc):
        x = jax.lax.population_count(scc[:, None, :] ^ dcc[None, :, :])
        return jnp.sum(x, axis=-1)

    # chunk-pad words are 0 on both sides -> xor 0 -> popcount 0: neutral
    disagreements = _match._scan_chunks(
        _match._pad_axis1(d, 8, 0), _match._pad_axis1(s, 8, 0), 8, combine)
    return bits_total - disagreements


def pack_buckets(sigs: jnp.ndarray) -> jnp.ndarray:
    """Minhash bucket ids int [N, m] -> uint8 [N, m].

    Raises ValueError when a bucket id falls outside [0, PACKED_BUCKET_MAX]
    (254/255 are the kernel pad sentinels) -- the PACKED layout applies to
    byte-sized rehash domains; keep WIDE (or rehash to <= 254 buckets) above
    that.
    """
    arr = jnp.asarray(sigs)
    lo, hi = int(arr.min()), int(arr.max())
    if lo < 0 or hi > PACKED_BUCKET_MAX:
        raise ValueError(
            f"PACKED TANIMOTO signatures must lie in [0, {PACKED_BUCKET_MAX}] "
            f"(254/255 are pad sentinels); got values in [{lo}, {hi}] -- "
            f"use SignatureLayout.WIDE or rehash to <= {PACKED_BUCKET_MAX + 1} "
            f"buckets"
        )
    return arr.astype(jnp.uint8)


def packed_tanimoto_match(data_u8: jnp.ndarray,
                          query_u8: jnp.ndarray) -> jnp.ndarray:
    """Byte-lane collision count: the pure-jnp reference for the packed
    TANIMOTO layout (identical counts to match_tanimoto on the int32 ids)."""
    return _match.match_eq(data_u8.astype(jnp.int32),
                           query_u8.astype(jnp.int32))


def packed_bytes_cosine(wide: jnp.ndarray) -> int:
    """Packed footprint of a WIDE sign matrix [N, V]: ceil(V/32) words/row."""
    return int(wide.shape[0]) * packed_words(int(wide.shape[1])) * 4


def packed_bytes_tanimoto(wide: jnp.ndarray) -> int:
    """Packed footprint of a WIDE sketch matrix [N, m]: one byte per slot."""
    return int(wide.shape[0]) * int(wide.shape[1])
