"""Postings-list (CSR) inverted-index engine + load balancing (paper III-B).

This is the GPU-faithful engine: an explicit inverted index with one postings
list per keyword, kept for (a) the CPU-Idx baseline of the paper's
experiments and (b) the load-balance study (Fig 4 / Fig 12): long postings
lists are split into fixed-size sub-lists ("one block takes at most two 4K
sub-lists"); on TPU the analogous effect is padding waste -- an unsplit engine
pads every scanned list to the global maximum length, a split engine works on
uniform tiles.

The TPU-native hot path is the dense engine in core/match.py; this module is
correctness-checked against it (same match counts).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import IndexStats


@dataclasses.dataclass
class PostingsIndex:
    """CSR inverted index over keyword ids in [0, n_keywords)."""

    n_objects: int
    n_keywords: int
    indptr: np.ndarray      # [n_keywords + 1]
    indices: np.ndarray     # [total_postings]  object ids, list-major
    stats: IndexStats

    @classmethod
    def build(cls, keywords: np.ndarray, n_keywords: int) -> "PostingsIndex":
        """keywords: int [N, m] -- m keyword ids per object (LSH signatures
        offset by function index, n-gram bucket ids, (attr, value) codes...)."""
        # perf_counter, not time(): a wall-clock (NTP) step must never record
        # a negative build duration
        t0 = time.perf_counter()
        n, m = keywords.shape
        flat = keywords.astype(np.int64).ravel()
        obj = np.repeat(np.arange(n, dtype=np.int32), m)
        order = np.argsort(flat, kind="stable")
        flat_sorted = flat[order]
        indices = obj[order]
        counts = np.bincount(flat_sorted, minlength=n_keywords)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        stats = IndexStats(
            n_objects=n,
            n_lists=int(np.sum(counts > 0)),
            total_postings=int(flat.size),
            max_list_len=int(counts.max()) if counts.size else 0,
            bytes_device=int(indices.nbytes + indptr.nbytes),
            build_seconds=time.perf_counter() - t0,
        )
        return cls(n_objects=n, n_keywords=n_keywords, indptr=indptr, indices=indices, stats=stats)

    # ------------------------------------------------------------------
    # CPU-Idx baseline (paper competitor): pure numpy postings scan.
    # ------------------------------------------------------------------
    def scan_counts_numpy(self, query_keywords: np.ndarray) -> np.ndarray:
        """counts [Q, N]: scan the matched postings lists per query."""
        q, m = query_keywords.shape
        out = np.zeros((q, self.n_objects), dtype=np.int32)
        for qi in range(q):
            for kw in query_keywords[qi]:
                s, e = self.indptr[kw], self.indptr[kw + 1]
                np.add.at(out[qi], self.indices[s:e], 1)
        return out

    # ------------------------------------------------------------------
    # Tiled device engine with the paper's sub-list splitting.
    # ------------------------------------------------------------------
    def split_tiles(self, limit: int = 4096) -> tuple[np.ndarray, np.ndarray]:
        """Split postings lists into <=limit-sized sub-lists (paper Fig 4).

        Returns (tiles [T, limit] int32, object ids padded with -1;
                 tile_keyword [T] int32, owning keyword of each tile).
        When limit >= max_list_len this degenerates to one padded tile per
        list -- the "no load balance" configuration whose padding waste is the
        TPU analogue of GPU block imbalance.
        """
        tiles, tile_kw = [], []
        for kw in range(self.n_keywords):
            s, e = int(self.indptr[kw]), int(self.indptr[kw + 1])
            if s == e:
                continue
            seg = self.indices[s:e]
            for off in range(0, len(seg), limit):
                sub = seg[off : off + limit]
                pad = np.full(limit, -1, dtype=np.int32)
                pad[: len(sub)] = sub
                tiles.append(pad)
                tile_kw.append(kw)
        if not tiles:
            return np.zeros((0, limit), np.int32), np.zeros((0,), np.int32)
        return np.stack(tiles), np.asarray(tile_kw, dtype=np.int32)

    def scan_counts_tiled(
        self, tiles: jnp.ndarray, tile_kw: jnp.ndarray, query_keywords: jnp.ndarray
    ) -> jnp.ndarray:
        """JAX tiled postings scan: counts [Q, N] by scatter-add over active tiles.

        A tile is active for a query iff its keyword is among the query's m
        keywords; every active tile contributes +1 for each object id it holds.
        """
        n = self.n_objects

        def one_query(qkw):
            active = jnp.any(tile_kw[:, None] == qkw[None, :], axis=-1)  # [T]
            w = jnp.where(tiles >= 0, active[:, None], False)           # [T, L]
            flat_ids = jnp.where(tiles >= 0, tiles, 0).ravel()
            return jnp.zeros((n,), jnp.int32).at[flat_ids].add(
                w.ravel().astype(jnp.int32), mode="drop"
            )

        return jax.vmap(one_query)(query_keywords)
