"""Sequence decomposition for SA search (paper section V-A).

A sequence S is decomposed into ordered n-grams (gram, i) -- the i-th
occurrence of that gram (Example 5.1).  With ordered grams the match count is
MC(G(S), G(Q)) = sum_g min(c_S(g), c_Q(g))  (Lemma 5.1), which we compute on
device as a MINSUM over per-gram-type count vectors hashed into V buckets.

Bucketisation property (used by the filter): if gram types collide in a
bucket, min(a1+a2, b1+b2) >= min(a1,b1) + min(a2,b2), so the bucketised count
is an UPPER bound on the exact MC.  Theorem 5.1 admission ("MC >= L - n + 1 -
tau*n") therefore never loses a true candidate through bucketing; spurious
admissions are removed by verification (sa/verify.py).  Property-tested.
"""
from __future__ import annotations

import zlib

import numpy as np

ALPHABET = "abcdefghijklmnopqrstuvwxyz 0123456789"


def ngrams(s: str, n: int) -> list[str]:
    if len(s) < n:
        return []
    return [s[i : i + n] for i in range(len(s) - n + 1)]


def ordered_ngrams(s: str, n: int) -> list[tuple[str, int]]:
    """Ordered n-grams (gram, occurrence-index) of Example 5.1."""
    seen: dict[str, int] = {}
    out = []
    for g in ngrams(s, n):
        k = seen.get(g, 0)
        out.append((g, k))
        seen[g] = k + 1
    return out


def gram_bucket(gram: str, n_buckets: int) -> int:
    """Deterministic gram-type -> bucket hash (crc32; stable across runs)."""
    return zlib.crc32(gram.encode("utf-8")) % n_buckets


def count_vector(s: str, n: int, n_buckets: int, clip: int = 127) -> np.ndarray:
    """Per-bucket gram-type multiplicities (int32 [n_buckets], clipped)."""
    v = np.zeros(n_buckets, dtype=np.int32)
    for g in ngrams(s, n):
        v[gram_bucket(g, n_buckets)] += 1
    return np.minimum(v, clip)


def count_vectors(seqs: list[str], n: int, n_buckets: int) -> np.ndarray:
    return np.stack([count_vector(s, n, n_buckets) for s in seqs])


def exact_match_count(s: str, q: str, n: int) -> int:
    """Dict-based oracle for Lemma 5.1: sum_g min(c_s(g), c_q(g))."""
    cs: dict[str, int] = {}
    for g in ngrams(s, n):
        cs[g] = cs.get(g, 0) + 1
    cq: dict[str, int] = {}
    for g in ngrams(q, n):
        cq[g] = cq.get(g, 0) + 1
    return sum(min(c, cq.get(g, 0)) for g, c in cs.items())


def count_filter_bound(len_q: int, len_s: int, tau: int, n: int) -> int:
    """Theorem 5.1: ed(S, Q) <= tau  ==>  MC >= max(|Q|,|S|) - n + 1 - tau*n."""
    return max(len_q, len_s) - n + 1 - tau * n


def encode_sequences(seqs: list[str], max_len: int, alphabet: str = ALPHABET):
    """Pad-encode strings to int32 [K, max_len] + lengths (for the DP verifier).

    Unknown characters map to a shared id; padding uses -1 (never matches).
    """
    lut = {c: i for i, c in enumerate(alphabet)}
    arr = np.full((len(seqs), max_len), -1, dtype=np.int32)
    lens = np.zeros(len(seqs), dtype=np.int32)
    for i, s in enumerate(seqs):
        s = s[:max_len]
        lens[i] = len(s)
        for j, ch in enumerate(s):
            arr[i, j] = lut.get(ch, len(alphabet))
    return arr, lens
