"""Relational-table search (paper Example 2.1, section V-C, Adult experiment).

Continuous attributes are discretized into equal-width bins (the paper uses
1024); categorical attributes are integer codes.  A query is a per-attribute
range [lo, hi] (the paper's Adult queries use value +- 50 bins); the match
count is the number of attributes whose value falls in the query range --
computed by the RANGE engine without materialising the (attribute, value)
inverted index.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Discretizer:
    mins: np.ndarray      # [d]
    maxs: np.ndarray      # [d]
    n_bins: int

    def transform(self, values: np.ndarray) -> np.ndarray:
        span = np.maximum(self.maxs - self.mins, 1e-12)
        bins = np.floor((values - self.mins) / span * self.n_bins).astype(np.int32)
        return np.clip(bins, 0, self.n_bins - 1)


def fit_discretizer(values: np.ndarray, n_bins: int = 1024) -> Discretizer:
    return Discretizer(mins=values.min(axis=0), maxs=values.max(axis=0), n_bins=n_bins)


def point_range_queries(
    discrete_tuples: np.ndarray, radius: int = 50, n_bins: int = 1024
) -> tuple[np.ndarray, np.ndarray]:
    """Paper's Adult query model: [value - radius, value + radius] per attribute."""
    lo = np.clip(discrete_tuples - radius, 0, n_bins - 1).astype(np.int32)
    hi = np.clip(discrete_tuples + radius, 0, n_bins - 1).astype(np.int32)
    return lo, hi


def exact_range_count(data: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Oracle: counts [Q, N] = #attributes of each tuple inside each range."""
    hit = (data[None, :, :] >= lo[:, None, :]) & (data[None, :, :] <= hi[:, None, :])
    return hit.sum(axis=-1).astype(np.int32)
