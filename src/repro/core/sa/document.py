"""Short-document SA search (paper section V-B).

Documents are broken into words; the match count between binary word vectors
is their inner product (the binary vector-space model), computed on the MXU
via the IP engine.  Stop-word removal mirrors the paper's Tweets pipeline.
"""
from __future__ import annotations

import re
import zlib

import numpy as np

_WORD_RE = re.compile(r"[a-z0-9']+")

STOP_WORDS = frozenset(
    "a an and are as at be by for from has he in is it its of on that the to was were will with".split()
)


def tokenize(doc: str, remove_stop_words: bool = True) -> list[str]:
    words = _WORD_RE.findall(doc.lower())
    if remove_stop_words:
        words = [w for w in words if w not in STOP_WORDS]
    return words


def word_bucket(word: str, n_buckets: int) -> int:
    return zlib.crc32(word.encode("utf-8")) % n_buckets


def binary_vector(doc: str, n_buckets: int, remove_stop_words: bool = True) -> np.ndarray:
    v = np.zeros(n_buckets, dtype=np.int8)
    for w in tokenize(doc, remove_stop_words):
        v[word_bucket(w, n_buckets)] = 1
    return v


def binary_vectors(docs: list[str], n_buckets: int, remove_stop_words: bool = True) -> np.ndarray:
    return np.stack([binary_vector(d, n_buckets, remove_stop_words) for d in docs])


def exact_overlap(a: str, b: str, remove_stop_words: bool = True) -> int:
    """Oracle: |words(a) & words(b)| (binary inner product)."""
    return len(set(tokenize(a, remove_stop_words)) & set(tokenize(b, remove_stop_words)))
