from repro.core.sa import document, ngram, relational, verify  # noqa: F401
