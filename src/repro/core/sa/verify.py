"""Verification for SA sequence search (paper Algorithm 2 + Theorem 5.2).

The GPU verifies candidates serially with an early-break (Alg 2 lines 5-6);
on TPU we verify the whole K-candidate list in parallel with a vectorised
Wagner-Fischer DP (batched over candidates), then apply the same filters and
Theorem 5.2 certificate.  Results are identical: the early break only skips
work, never changes the answer (DESIGN.md section 2, adaptation note 3).

The row update of the DP is vectorised with the min-plus prefix trick: with
t[i] = min(prev[i-1] + sub_i, prev[i] + 1), the insertion recurrence
new[i] = min(t[i], new[i-1] + 1) solves to new[i] = i + cummin_{i'<=i}(t[i'] - i'),
turning the sequential dependency into a cummin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sa import ngram as _ngram


def edit_distance(a: jnp.ndarray, la: jnp.ndarray, b: jnp.ndarray, lb: jnp.ndarray) -> jnp.ndarray:
    """Edit distance between padded int sequences a [La] and b [Lb].

    Padding must be a value that never equals a real symbol (-1 vs -2 are used
    by callers so padded tails never match each other).
    """
    La = a.shape[0]
    idx = jnp.arange(La + 1, dtype=jnp.int32)
    row0 = idx  # D[0, i] = i

    a_ext = jnp.concatenate([jnp.array([-3], dtype=a.dtype), a])  # 1-based

    def step(prev, bj):
        sub = (a_ext[1:] != bj).astype(jnp.int32)           # [La]
        t = jnp.minimum(prev[:-1] + sub, prev[1:] + 1)      # [La] for i=1..La
        # new[i] = min(t[i], new[i-1] + 1); new[0] = prev[0] + 1
        lead = prev[0] + 1
        shifted = jnp.concatenate([jnp.array([lead], jnp.int32), t]) - idx
        new_tail = jax.lax.cummin(shifted)[1:] + idx[1:]
        new = jnp.concatenate([jnp.array([lead], jnp.int32), new_tail])
        return new, new

    _, rows = jax.lax.scan(step, row0, b)
    rows = jnp.concatenate([row0[None], rows], axis=0)      # [Lb+1, La+1]
    return rows[lb, la]


def edit_distance_one_to_many(
    query: jnp.ndarray, q_len: jnp.ndarray, cands: jnp.ndarray, c_lens: jnp.ndarray
) -> jnp.ndarray:
    """ed(query, cand_k) for K padded candidates.  query [Lq], cands [K, Lc]."""
    return jax.vmap(lambda b, lb: edit_distance(query, q_len, b, lb))(cands, c_lens)


def verify_topk(
    query: jnp.ndarray,
    q_len: jnp.ndarray,
    cand_seqs: jnp.ndarray,
    cand_lens: jnp.ndarray,
    cand_counts: jnp.ndarray,
    k: int,
    n: int,
) -> dict:
    """Batched Algorithm 2: exact edit distances for the K GENIE candidates,
    the best-k by edit distance, and Theorem 5.2's exactness certificate.

    cand_counts must be sorted descending (GENIE returns them so); invalid
    candidate slots are marked by cand_lens == 0.
    """
    kk = cand_seqs.shape[0]
    valid = cand_lens > 0
    big = jnp.int32(10**6)
    eds = jnp.where(valid, edit_distance_one_to_many(query, q_len, cand_seqs, cand_lens), big)
    # top-k by (edit distance asc); lax.top_k on negated values
    neg = -(eds.astype(jnp.int32))
    vals, order = jax.lax.top_k(neg, min(k, kk))
    best_eds = -vals
    # Theorem 5.2: exact iff c_K < |Q| - n + 1 - tau_k' * n
    tau_k = best_eds[-1]
    c_K = cand_counts[-1]
    bound = q_len - n + 1 - tau_k * n
    certified = c_K < bound
    return dict(order=order, edit_distances=best_eds, certified_exact=certified, tau_k=tau_k)
