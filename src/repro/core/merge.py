"""Hierarchical top-k merge (paper section III-D's host merge, generalised).

The paper's multiple-loading strategy searches index parts independently and
merges per-part top-k results on the CPU.  At pod scale the same reduction
becomes a collective: every shard produces a cap-sized candidate buffer
(c-PQ Hash Table) and buffers are merged pairwise/hierarchically -- the merge
of two valid top-k buffers is a valid top-k buffer of the union (counts are
per-object totals when objects are *partitioned* across shards, so no
cross-shard count summation is needed).

These primitives are called only from the unified executor (core/plan.py),
which picks the strategy per layout: `merge_ragged` for host-streamed
heterogeneous parts, `merge_topk` for the distributed all-gather.

merge_topk    -- host/XLA merge of stacked per-part results.
tree_merge    -- log2(S) pairwise merge (the collective-friendly schedule).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cpq as _cpq
from repro.core.types import TopKResult


def merge_topk(ids: jnp.ndarray, counts: jnp.ndarray, k: int) -> TopKResult:
    """Merge per-part results.  ids/counts: int32 [S, Q, kp] (part-LOCAL top-k,
    ids already globalised) -> overall top-k [Q, k]."""
    s, q, kp = ids.shape
    flat_ids = jnp.transpose(ids, (1, 0, 2)).reshape(q, s * kp)
    flat_counts = jnp.transpose(counts, (1, 0, 2)).reshape(q, s * kp)
    out_ids, out_counts = _cpq.topk_from_candidates(flat_ids, flat_counts, k)
    return TopKResult(ids=out_ids, counts=out_counts, threshold=out_counts[:, -1])


def merge_ragged(ids_list, counts_list, k: int) -> TopKResult:
    """Merge per-part top-k buffers of *heterogeneous* widths.

    ids_list/counts_list: per-part int32 [Q, kp_i] buffers (kp_i may differ --
    a part smaller than k contributes only min(k, n_part) candidates), ids
    already globalised.  Parts must partition the object set and arrive in
    ascending global-id order: the flattened candidate row is then globally
    id-ascending within equal counts, so the stable selection reproduces the
    monolithic (count desc, id asc) ordering exactly.
    """
    ids = jnp.concatenate(ids_list, axis=-1)
    counts = jnp.concatenate(counts_list, axis=-1)
    if ids.shape[-1] < k:  # fewer total candidates than k: pad empty slots
        pad = jnp.full((ids.shape[0], k - ids.shape[-1]), -1, dtype=jnp.int32)
        ids = jnp.concatenate([ids, pad], axis=-1)
        counts = jnp.concatenate([counts, pad], axis=-1)
    out_ids, out_counts = _cpq.topk_from_candidates(ids, counts, k)
    return TopKResult(ids=out_ids, counts=out_counts, threshold=out_counts[:, -1])


def merge_two(
    ids_a: jnp.ndarray, counts_a: jnp.ndarray, ids_b: jnp.ndarray, counts_b: jnp.ndarray, k: int
):
    """Pairwise merge of two [Q, k] buffers -> [Q, k]."""
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    counts = jnp.concatenate([counts_a, counts_b], axis=-1)
    return _cpq.topk_from_candidates(ids, counts, k)


def tree_merge(ids: jnp.ndarray, counts: jnp.ndarray, k: int):
    """log2(S) pairwise merge of [S, Q, kp] buffers (ids globalised).

    Mirrors the recursive-doubling schedule a pod-level collective merge uses;
    produces identical results to merge_topk (tested).
    """
    s = ids.shape[0]
    while s > 1:
        half = (s + 1) // 2
        a_ids, a_cnt = ids[:half], counts[:half]
        b_ids = jnp.concatenate([ids[half:], jnp.full_like(ids[: 2 * half - s], -1)], axis=0)
        b_cnt = jnp.concatenate(
            [counts[half:], jnp.full_like(counts[: 2 * half - s], -1)], axis=0
        )
        merged_ids, merged_cnt = merge_two(a_ids, a_cnt, b_ids, b_cnt, min(k, a_ids.shape[-1] + b_ids.shape[-1]))
        ids, counts = merged_ids, merged_cnt
        s = half
    out_ids, out_counts = _cpq.topk_from_candidates(ids[0], counts[0], k)
    return TopKResult(ids=out_ids, counts=out_counts, threshold=out_counts[:, -1])
