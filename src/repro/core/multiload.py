"""Multiple loading (paper section III-D): search datasets larger than device
memory by streaming index parts and merging per-part top-k results.

On the GPU the parts are copied host->device serially; on TPU the parts are a
stacked HBM-resident array consumed by lax.scan (double-buffered by XLA), or a
host python loop when the stack itself exceeds HBM.  The per-part search is
the dense match + c-PQ select; the merge is core.merge (valid because parts
partition the object set -- counts never need cross-part summation).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import cpq as _cpq
from repro.core.types import SearchParams, TopKResult


def multiload_search(
    chunks: jnp.ndarray,
    query_sigs: jnp.ndarray,
    params: SearchParams,
    match_fn: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
) -> TopKResult:
    """Search C stacked index parts with a scanned merge.

    chunks:     [C, Nc, m]  stacked per-part signature matrices.
    query_sigs: [Q, m].
    match_fn:   (data [Nc, m], queries [Q, m]) -> counts [Q, Nc].
    """
    c, nc, _ = chunks.shape
    q = query_sigs.shape[0]
    k = params.k

    init = (
        jnp.full((q, k), -1, dtype=jnp.int32),
        jnp.full((q, k), -1, dtype=jnp.int32),
    )

    def step(carry, xs):
        best_ids, best_counts = carry
        part, chunk_idx = xs
        counts = match_fn(part, query_sigs)
        local = _cpq.cpq_select(counts, params)
        global_ids = jnp.where(local.ids >= 0, local.ids + chunk_idx * nc, -1)
        ids = jnp.concatenate([best_ids, global_ids[:, :k]], axis=-1)
        cnt = jnp.concatenate([best_counts, local.counts[:, :k]], axis=-1)
        new_ids, new_counts = _cpq.topk_from_candidates(ids, cnt, k)
        return (new_ids, new_counts), None

    (ids, counts), _ = jax.lax.scan(step, init, (chunks, jnp.arange(c, dtype=jnp.int32)))
    return TopKResult(ids=ids, counts=counts, threshold=counts[:, -1])


def multiload_search_host(parts, query_sigs, params, match_fn) -> TopKResult:
    """Host-loop variant: `parts` is a python list of per-part arrays that are
    device_put one at a time (the literal paper strategy -- parts live in host
    memory and are swapped through the device)."""
    q = query_sigs.shape[0]
    k = params.k
    best_ids = jnp.full((q, k), -1, dtype=jnp.int32)
    best_counts = jnp.full((q, k), -1, dtype=jnp.int32)
    offset = 0
    for part in parts:
        part = jax.device_put(part)
        counts = match_fn(part, query_sigs)
        local = _cpq.cpq_select(counts, params)
        gids = jnp.where(local.ids >= 0, local.ids + offset, -1)
        ids = jnp.concatenate([best_ids, gids[:, :k]], axis=-1)
        cnt = jnp.concatenate([best_counts, local.counts[:, :k]], axis=-1)
        best_ids, best_counts = _cpq.topk_from_candidates(ids, cnt, k)
        offset += int(part.shape[0])
    return TopKResult(ids=best_ids, counts=best_counts, threshold=best_counts[:, -1])
