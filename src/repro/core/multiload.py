"""Multiple loading (paper section III-D): search datasets larger than device
memory by streaming index parts and merging per-part top-k results.

Both entry points are thin adapters over the unified planner (core/plan.py):
they describe the part layout as a MULTILOAD `QueryPlan` and delegate to the
shared executor, which owns match dispatch, pad masking, per-part k-clamping,
selection, and the merge.

On the GPU the parts are copied host->device serially
(`multiload_search_host`, the literal paper strategy -- `host_loop=True`
plans); on TPU the parts are a stacked HBM-resident array consumed by
lax.scan (double-buffered by XLA) via `multiload_search`.

The match function uses the canonical registry signature
``match_fn(data, queries) -> counts`` (core/engines.py), so every registered
engine streams the same way -- queries may be any pytree of arrays (RANGE
passes the ``(lo, hi)`` pair) since they are closed over, not scanned.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax.numpy as jnp

from repro.core import plan as _plan
from repro.core.types import SearchParams, TopKResult

# Back-compat aliases: the pad-mask implementations now live in the executor
# module (core/plan.py), the only code that calls them.
_mask_pad_counts = _plan._mask_pad_counts
_mask_invalid = _plan._mask_invalid


def _multiload_plan(part_rows, params: SearchParams, match_fn,
                    n_objects: Optional[int], host_loop: bool) -> _plan.QueryPlan:
    return _plan.plan_search(
        match_fn, params.k, params.max_count, layout=_plan.Layout.MULTILOAD,
        part_rows=part_rows, n_objects=n_objects, method=params.method,
        candidate_cap=params.candidate_cap, use_kernel=params.use_kernel,
        host_loop=host_loop,
    )


def multiload_search(
    chunks: jnp.ndarray,
    queries: Any,
    params: SearchParams,
    match_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
    n_objects: Optional[int] = None,
) -> TopKResult:
    """Search C stacked index parts with a scanned merge.

    chunks:    [C, Nc, ...] stacked per-part data matrices.
    queries:   canonical query pytree (single [Q, m] array for EQ/MINSUM/IP,
               an (lo, hi) pair for RANGE).
    match_fn:  (data [Nc, ...], queries) -> counts [Q, Nc].
    n_objects: true object count; rows with global id >= n_objects are
               padding from an uneven split and are masked out.
    """
    part_rows = (int(chunks.shape[1]),) * int(chunks.shape[0])
    plan = _multiload_plan(part_rows, params, match_fn, n_objects, host_loop=False)
    return _plan.execute(plan, chunks, queries)


def multiload_search_host(parts, queries, params, match_fn,
                          n_objects: Optional[int] = None) -> TopKResult:
    """Host-loop variant: `parts` is a python list of per-part arrays that are
    device_put one at a time (the literal paper strategy -- parts live in host
    memory and are swapped through the device).

    Parts may have *heterogeneous* sizes (SegmentedIndex streams its sealed
    segments through here); a part smaller than k contributes only
    min(k, n_part) candidates.
    """
    part_rows = tuple(int(p.shape[0]) for p in parts)
    plan = _multiload_plan(part_rows, params, match_fn, n_objects, host_loop=True)
    return _plan.execute(plan, list(parts), queries)
