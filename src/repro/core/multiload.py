"""Multiple loading (paper section III-D): search datasets larger than device
memory by streaming index parts and merging per-part top-k results.

On the GPU the parts are copied host->device serially; on TPU the parts are a
stacked HBM-resident array consumed by lax.scan (double-buffered by XLA), or a
host python loop when the stack itself exceeds HBM.  The per-part search is
the dense match + shared `select_topk` pipeline; the merge is core.merge
(valid because parts partition the object set -- counts never need cross-part
summation).

The match function uses the canonical registry signature
``match_fn(data, queries) -> counts`` (core/engines.py), so every registered
engine streams the same way -- queries may be any pytree of arrays (RANGE
passes the ``(lo, hi)`` pair) since they are closed over, not scanned.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import cpq as _cpq
from repro.core.select import select_topk
from repro.core.types import SearchParams, TopKResult


def _mask_invalid(gids: jnp.ndarray, counts: jnp.ndarray, n_objects: Optional[int]):
    """Drop padding rows: ids at/above the true object count never merge."""
    valid = gids >= 0
    if n_objects is not None:
        valid &= gids < n_objects
    return jnp.where(valid, gids, -1), jnp.where(valid, counts, -1)


def _mask_pad_counts(counts: jnp.ndarray, offset, n_objects: Optional[int]) -> jnp.ndarray:
    """Force pad columns (global id >= n_objects) to count -1 *before*
    selection, so pad rows can never crowd real candidates out of the per-part
    top-k buffer.  This makes pad safety structural for every engine: the
    `pad_value` fill only has to be representable, not score-neutral (COSINE's
    zero rows, for instance, score V/2 against any query)."""
    if n_objects is None:
        return counts
    gcol = offset + jnp.arange(counts.shape[-1], dtype=jnp.int32)
    return jnp.where((gcol < n_objects)[None, :], counts, -1)


def multiload_search(
    chunks: jnp.ndarray,
    queries: Any,
    params: SearchParams,
    match_fn: Callable[[jnp.ndarray, Any], jnp.ndarray],
    n_objects: Optional[int] = None,
) -> TopKResult:
    """Search C stacked index parts with a scanned merge.

    chunks:    [C, Nc, ...] stacked per-part data matrices.
    queries:   canonical query pytree (single [Q, m] array for EQ/MINSUM/IP,
               an (lo, hi) pair for RANGE).
    match_fn:  (data [Nc, ...], queries) -> counts [Q, Nc].
    n_objects: true object count; rows with global id >= n_objects are
               padding from an uneven split and are masked out.
    """
    c, nc = chunks.shape[0], chunks.shape[1]
    q = jax.tree_util.tree_leaves(queries)[0].shape[0]
    k = params.k

    init = (
        jnp.full((q, k), -1, dtype=jnp.int32),
        jnp.full((q, k), -1, dtype=jnp.int32),
    )

    def step(carry, xs):
        best_ids, best_counts = carry
        part, chunk_idx = xs
        counts = _mask_pad_counts(match_fn(part, queries), chunk_idx * nc, n_objects)
        local = select_topk(counts, params)
        global_ids = jnp.where(local.ids >= 0, local.ids + chunk_idx * nc, -1)
        gids, gcnt = _mask_invalid(global_ids, local.counts, n_objects)
        ids = jnp.concatenate([best_ids, gids[:, :k]], axis=-1)
        cnt = jnp.concatenate([best_counts, gcnt[:, :k]], axis=-1)
        new_ids, new_counts = _cpq.topk_from_candidates(ids, cnt, k)
        return (new_ids, new_counts), None

    (ids, counts), _ = jax.lax.scan(step, init, (chunks, jnp.arange(c, dtype=jnp.int32)))
    return TopKResult(ids=ids, counts=counts, threshold=counts[:, -1])


def multiload_search_host(parts, queries, params, match_fn,
                          n_objects: Optional[int] = None) -> TopKResult:
    """Host-loop variant: `parts` is a python list of per-part arrays that are
    device_put one at a time (the literal paper strategy -- parts live in host
    memory and are swapped through the device).

    Parts may have *heterogeneous* sizes (SegmentedIndex streams its sealed
    segments through here); a part smaller than k contributes only
    min(k, n_part) candidates.
    """
    q = jax.tree_util.tree_leaves(queries)[0].shape[0]
    k = params.k
    best_ids = jnp.full((q, k), -1, dtype=jnp.int32)
    best_counts = jnp.full((q, k), -1, dtype=jnp.int32)
    offset = 0
    for part in parts:
        part = jax.device_put(part)
        counts = _mask_pad_counts(match_fn(part, queries), offset, n_objects)
        local = select_topk(counts,
                            dataclasses.replace(params, k=min(k, int(part.shape[0]))))
        gids = jnp.where(local.ids >= 0, local.ids + offset, -1)
        gids, gcnt = _mask_invalid(gids, local.counts, n_objects)
        ids = jnp.concatenate([best_ids, gids[:, :k]], axis=-1)
        cnt = jnp.concatenate([best_counts, gcnt[:, :k]], axis=-1)
        best_ids, best_counts = _cpq.topk_from_candidates(ids, cnt, k)
        offset += int(part.shape[0])
    return TopKResult(ids=best_ids, counts=best_counts, threshold=best_counts[:, -1])
