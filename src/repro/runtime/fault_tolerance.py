"""Fault-tolerance runtime for 1000+-node deployments (CPU-testable logic).

At pod scale the failure model is: hosts heartbeat to a coordinator; a missed
heartbeat or a crashed step triggers (a) restart-in-place from the latest
checkpoint when the host pool is intact, or (b) an elastic re-mesh onto the
surviving hosts.  Straggler mitigation watches per-step wall times and flags
hosts whose EWMA deviates from the fleet median (on TPU pods a straggler is
usually a thermally-throttled or pre-failing chip; the mitigation is to
checkpoint and evict).

These classes carry the *policy* logic -- deterministic and unit-tested here;
the trainer (train/trainer.py) wires them to real steps, and on a real
deployment the heartbeat transport would be the cluster scheduler.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks host liveness from heartbeat timestamps."""

    n_hosts: int
    timeout_s: float = 60.0
    _last: dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host_id: int, now: Optional[float] = None) -> None:
        # deliberately time.time(), not perf_counter(): heartbeats are
        # compared against deadlines that must be meaningful *across*
        # processes and hosts (the coordinator and the beating host are not
        # the same machine), and perf_counter's epoch is process-local.
        # Duration measurements elsewhere use perf_counter; liveness
        # deadlines use wall-clock by design.
        self._last[host_id] = time.time() if now is None else now

    def alive(self, now: Optional[float] = None) -> list[int]:
        now = time.time() if now is None else now
        return [h for h in range(self.n_hosts)
                if now - self._last.get(h, -math.inf) <= self.timeout_s]

    def dead(self, now: Optional[float] = None) -> list[int]:
        alive = set(self.alive(now))
        return [h for h in range(self.n_hosts) if h not in alive]


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time tracking; flags hosts slower than `ratio` x fleet median."""

    n_hosts: int
    alpha: float = 0.2
    ratio: float = 1.5
    min_samples: int = 5
    _ewma: dict[int, float] = dataclasses.field(default_factory=dict)
    _count: dict[int, int] = dataclasses.field(default_factory=dict)

    def record(self, host_id: int, step_seconds: float) -> None:
        prev = self._ewma.get(host_id)
        self._ewma[host_id] = (
            step_seconds if prev is None else self.alpha * step_seconds + (1 - self.alpha) * prev
        )
        self._count[host_id] = self._count.get(host_id, 0) + 1

    def median(self) -> float:
        vals = sorted(self._ewma.values())
        if not vals:
            return 0.0
        mid = len(vals) // 2
        return vals[mid] if len(vals) % 2 else 0.5 * (vals[mid - 1] + vals[mid])

    def stragglers(self) -> list[int]:
        med = self.median()
        if med <= 0:
            return []
        return [
            h for h, v in self._ewma.items()
            if self._count.get(h, 0) >= self.min_samples and v > self.ratio * med
        ]


@dataclasses.dataclass
class RestartPolicy:
    """Bounded restarts with exponential backoff."""

    max_restarts: int = 10
    backoff_base_s: float = 1.0
    backoff_cap_s: float = 300.0
    restarts: int = 0

    def on_failure(self) -> float:
        """Returns backoff seconds; raises when the budget is exhausted."""
        if self.restarts >= self.max_restarts:
            raise RuntimeError(f"restart budget exhausted ({self.max_restarts})")
        delay = min(self.backoff_base_s * (2.0 ** self.restarts), self.backoff_cap_s)
        self.restarts += 1
        return delay

    def on_success_window(self) -> None:
        """Call after a healthy window to forgive old failures."""
        self.restarts = max(0, self.restarts - 1)


def elastic_mesh_shape(alive_hosts: int, chips_per_host: int, model_parallel: int,
                       pod_size_chips: int = 256) -> tuple[int, ...]:
    """Propose a (pod, data, model) mesh for the surviving fleet.

    Keeps `model_parallel` fixed (TP degree is architecture-bound), shrinks
    the data axis to the largest multiple that fits, and re-forms pods of
    `pod_size_chips`.  Returns () when nothing trainable remains.
    """
    chips = alive_hosts * chips_per_host
    if chips < model_parallel:
        return ()
    data = chips // model_parallel
    pods = max(chips // pod_size_chips, 1)
    data_per_pod = data // pods
    while pods > 1 and data_per_pod == 0:
        pods -= 1
        data_per_pod = data // pods
    if pods > 1:
        return (pods, data_per_pod, model_parallel)
    return (data, model_parallel)
