from repro.runtime.fault_tolerance import (  # noqa: F401
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
    elastic_mesh_shape,
)
