"""Error-feedback int8 gradient compression (1-bit-Adam-family trick).

Gradients are quantised to int8 with a per-tensor scale before the
data-parallel reduction; the quantisation residual is fed back into the next
step so the compression error does not accumulate (error-feedback guarantees
convergence for smooth objectives).  4x reduction of gradient all-reduce
bytes -- a collective-term lever recorded in EXPERIMENTS.md section Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tensor(g: jnp.ndarray, err: jnp.ndarray):
    """Returns (quantised int8, scale, new_error).  deq = q * scale."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, x - deq


def apply(grads, errors):
    """Compress+decompress every leaf with error feedback.

    Returns (dequantised grads -- what the reduced/optimizer path sees,
    new error state).  Under pjit the int8 representation is what crosses
    the data-parallel reduction when this is applied per-shard pre-reduce.
    """
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    deqs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, scale, ne = compress_tensor(g, e)
        deqs.append((q.astype(jnp.float32) * scale).astype(g.dtype))
        errs.append(ne)
    return tdef.unflatten(deqs), tdef.unflatten(errs)
