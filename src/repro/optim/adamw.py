"""AdamW optimizer (no external deps), pytree-native, fp32 moments.

Decoupled weight decay, bias-corrected moments, global-norm clipping.
Moments can optionally be kept in bf16 ("low_mem") -- one of the memory-term
hillclimb levers recorded in EXPERIMENTS.md section Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    m: Any
    v: Any
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # "bfloat16" halves optimizer memory


def init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), norm


def update(
    grads, state: AdamWState, params, cfg: AdamWConfig, lr: Optional[jnp.ndarray] = None
):
    """Returns (new_params, new_state, grad_norm)."""
    lr = cfg.lr if lr is None else lr
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g32 * g32 * (1.0 - cfg.b2)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(m=new_m, v=new_v, step=step), gnorm
