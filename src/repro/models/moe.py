"""Mixture-of-Experts FFN: token-choice top-k routing with capacity-bounded
sort-free dispatch (qwen2-moe: 60 routed top-4 + shared experts; grok-1: 8
routed top-2).

Dispatch is the gather/scatter formulation (not the one-hot einsum): tokens
are placed into [E, C] expert buffers via a cumulative-position scatter, each
expert runs a dense SwiGLU on its buffer (active-expert FLOPs only --
6*N_active*D, which is what the roofline's MODEL_FLOPS ratio checks), and
results are combined back with routing weights.  Overflow tokens beyond
capacity C = ceil(T * top_k / E * capacity_factor) are dropped (standard
token-choice behaviour); the router is trained with the usual load-balance
auxiliary loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.partition import hint


def init_moe(key, cfg: ModelConfig, dtype, out_scale: float) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": L.dense_init(ks[0], (d, e), s, jnp.float32),  # router in fp32
        "w_gate": L.dense_init(ks[1], (e, d, fe), s, dtype),
        "w_up": L.dense_init(ks[2], (e, d, fe), s, dtype),
        "w_down": L.dense_init(ks[3], (e, fe, d), out_scale / math.sqrt(fe), dtype),
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = L.init_mlp(ks[4], cfg, dtype, out_scale, d_ff=cfg.shared_expert_d_ff)
        p["shared_gate"] = L.dense_init(ks[5], (d, 1), s, dtype)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_top_k * cfg.capacity_factor / cfg.n_experts))
    # round up to a shardable multiple: an indivisible capacity replicates the
    # [E, C, D] buffers across the mesh (qwen2-moe prefill: C=87382 -> 89 GB/dev
    # measured; EXPERIMENTS.md Perf A3b).  512 = pod*data*model.
    if c > 512:
        c = -(-c // 512) * 512
    return max(c, 1)


def moe_ffn(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.experts_top_k
    c = capacity(t, cfg)
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                        # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)                          # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)          # renormalise

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                                    # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)

    # --- dispatch: position of each (token, slot) inside its expert buffer ---
    # All buffer state stays [E, C]-shaped: flat [E*C] reshapes between
    # differently-sharded layouts forced GSPMD into three 64 GB/layer
    # buffer all-gathers on grok (measured; EXPERIMENTS.md Perf hillclimb A).
    flat_e = top_e.reshape(-1)                                      # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)             # [T*k, E]
    pos_in_e = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    scatter_idx = jnp.stack([flat_e, pos_in_e], axis=-1)            # [T*k, 2]
    # out-of-capacity slots (pos_in_e >= C) fall outside the buffer and are
    # dropped by scatter mode="drop" -- the token-choice dropping policy.
    buf_tok = jnp.zeros((e, c), jnp.int32).at[
        scatter_idx[:, 0], scatter_idx[:, 1]].set(token_of, mode="drop")
    buf_used = jnp.zeros((e, c), jnp.bool_).at[
        scatter_idx[:, 0], scatter_idx[:, 1]].set(True, mode="drop")
    buf_w = jnp.zeros((e, c), jnp.float32).at[
        scatter_idx[:, 0], scatter_idx[:, 1]].set(top_p.reshape(-1), mode="drop")

    # Replicate the token activations once, then gather locally: a cross-shard
    # gather is otherwise lowered as a full [E, C, D] all-reduce.
    xf_rep = hint(xf, None, None)
    x_buf = jnp.take(xf_rep, buf_tok, axis=0)                       # [E, C, D]
    x_buf = x_buf * buf_used[..., None].astype(x_buf.dtype)
    x_buf = hint(x_buf, "tp" if e % 16 == 0 else None, "dp", None)

    # --- expert computation (dense per-expert SwiGLU) ---
    # Weight hints force "gather the FSDP weight shards, not the buffers" --
    # correct when buffers outweigh weights (training/prefill).  In decode the
    # buffers are ~C*k tokens and the weights are tens of GB: keep the weights
    # sharded and let GSPMD move the (tiny) buffers instead.
    gather_weights = t * 3 * k >= cfg.n_experts * cfg.moe_d_ff  # buffer rows vs d_ff rows
    wrole = "rep" if gather_weights else "dp"
    wg = hint(p["w_gate"].astype(x_buf.dtype), None, wrole, "tp")
    wu = hint(p["w_up"].astype(x_buf.dtype), None, wrole, "tp")
    wd = hint(p["w_down"].astype(x_buf.dtype), None, "tp", wrole)
    gate = hint(jnp.einsum("ecd,edf->ecf", x_buf, wg), None, "dp", "tp")
    up = hint(jnp.einsum("ecd,edf->ecf", x_buf, wu), None, "dp", "tp")
    y_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, wd)  # [E, C, D]

    # --- combine: weight in buffer space, scatter-add back to token space ---
    y_buf = y_buf * (buf_w * buf_used.astype(jnp.float32)).astype(y_buf.dtype)[..., None]
    y = jnp.zeros((t, d), y_buf.dtype).at[buf_tok].add(y_buf, mode="drop")
    y = hint(y, "dp", None)

    if "shared" in p:
        sh = L.mlp_block(x, p["shared"], cfg)
        sg = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x.astype(jnp.float32), p["shared_gate"].astype(jnp.float32))
        ).astype(x.dtype)
        y = y.reshape(b, s, d) + sh * sg
        return y, aux
    return y.reshape(b, s, d), aux
