"""Encoder-decoder backbone (seamless-m4t-large-v2: 24L speech encoder + 24L
text decoder, d_model 1024, 16 heads, d_ff 8192, vocab 256206).

The modality frontend is a STUB per the assignment: `input_specs` feeds
precomputed frame embeddings [B, S_src, D] (the conformer feature extractor
is out of scope); the transformer backbone -- bidirectional encoder, causal
decoder with cross-attention -- is fully implemented.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.partition import hint


def init_dec_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(2 * (cfg.n_layers + cfg.n_encoder_layers))
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype, out_scale),
        "xattn": L.init_attention(k2, cfg, dtype, out_scale),
        "mlp": L.init_mlp(k3, cfg, dtype, out_scale),
    }


def init_enc_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    out_scale = 1.0 / math.sqrt(2 * (cfg.n_layers + cfg.n_encoder_layers))
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype, out_scale),
        "mlp": L.init_mlp(k2, cfg, dtype, out_scale),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(
        jax.random.split(kenc, cfg.n_encoder_layers)
    )
    dec = jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(
        jax.random.split(kdec, cfg.n_layers)
    )
    return {
        "embed": L.dense_init(ke, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_ln": jnp.ones((cfg.d_model,), dtype),
        "final_ln": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(kh, (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(cfg.d_model), dtype),
    }


def encode(cfg: ModelConfig, params, frames: jnp.ndarray, *, remat: bool = True) -> jnp.ndarray:
    """frames [B, S_src, D] (stub embeddings) -> encoder memory [B, S_src, D]."""
    cd = L.cdtype(cfg)
    h = frames.astype(cd)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, lp):
        h = hint(h, "dp", "tp", None)   # sequence-parallel residual (iter 5)
        a, _ = L.attention_block(
            L.rms_norm(h, lp["ln1"], cfg.rms_eps), lp["attn"], cfg, positions, causal=False
        )
        h = h + a
        h = h + L.mlp_block(L.rms_norm(h, lp["ln2"], cfg.rms_eps), lp["mlp"], cfg)
        return h, None

    body = L.remat_wrap(body, remat)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"],
                        unroll=cfg.n_encoder_layers if cfg.scan_unroll else 1)
    return L.rms_norm(h, params["enc_ln"], cfg.rms_eps)


def _cross_kv(cfg, lp, memory):
    b, s, _ = memory.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,dk->bsk", memory, lp["xattn"]["wk"].astype(memory.dtype)).reshape(b, s, kvh, hd)
    v = jnp.einsum("bsd,dk->bsk", memory, lp["xattn"]["wv"].astype(memory.dtype)).reshape(b, s, kvh, hd)
    return k, v


def _dec_block(cfg, lp, h, positions, memory, cache=None, cache_pos=None):
    if cache is None:
        h = hint(h, "dp", "tp", None)   # sequence-parallel residual (iter 5)
    a, emitted = L.attention_block(
        L.rms_norm(h, lp["ln1"], cfg.rms_eps), lp["attn"], cfg, positions,
        causal=True, cache=cache, cache_pos=cache_pos,
    )
    h = h + a
    xk, xv = _cross_kv(cfg, lp, memory)
    xa, _ = L.attention_block(
        L.rms_norm(h, lp["ln_x"], cfg.rms_eps), lp["xattn"], cfg, positions,
        causal=False, kv_override=(xk, xv), use_rope=False,
    )
    h = h + xa
    h = h + L.mlp_block(L.rms_norm(h, lp["ln2"], cfg.rms_eps), lp["mlp"], cfg)
    return h, emitted


def forward(cfg: ModelConfig, params, frames: jnp.ndarray, tgt_tokens: jnp.ndarray,
            *, remat: bool = True, emit_kv: bool = False):
    """Teacher-forced seq2seq forward -> (logits [B, S_tgt, V], aux, kv)."""
    memory = encode(cfg, params, frames, remat=remat)
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], tgt_tokens, axis=0).astype(cd)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(h, lp):
        h2, emitted = _dec_block(cfg, lp, h, positions, memory)
        return h2, emitted if emit_kv else None

    body = L.remat_wrap(body, remat)
    h, kv = jax.lax.scan(body, h, params["dec_blocks"],
                         unroll=cfg.n_layers if cfg.scan_unroll else 1)
    hn = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", hn, params["lm_head"].astype(hn.dtype)).astype(jnp.float32)
    return logits, jnp.float32(0.0), (kv, memory)


def prefill(cfg: ModelConfig, params, frames, tgt_prefix, *, cache_cap: Optional[int] = None):
    logits, _, (kv, memory) = forward(cfg, params, frames, tgt_prefix, remat=False, emit_kv=True)
    ks, vs = kv
    s = ks.shape[2]
    cap = cache_cap or s
    if cap > s:
        pad = [(0, 0), (0, 0), (0, cap - s), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16), "memory": memory}
    return logits[:, -1, :], cache, jnp.int32(s)


def decode_step(cfg: ModelConfig, params, token, cache, pos):
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], token, axis=0).astype(cd)
    b = h.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    memory = cache["memory"]

    def body(h, xs):
        lp, ck, cv = xs
        h2, new_cache = _dec_block(
            cfg, lp, h, positions, memory, cache={"k": ck, "v": cv}, cache_pos=pos
        )
        return h2, (new_cache["k"], new_cache["v"])

    h, (nk, nv) = jax.lax.scan(body, h, (params["dec_blocks"], cache["k"], cache["v"]),
                               unroll=cfg.n_layers if cfg.scan_unroll else 1)
    hn = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", hn, params["lm_head"].astype(hn.dtype)).astype(jnp.float32)[:, 0, :]
    return logits, {"k": nk, "v": nv, "memory": memory}
