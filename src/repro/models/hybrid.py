"""Zamba2-style hybrid: a Mamba2 backbone with a single weight-SHARED
attention+MLP block applied every `shared_attn_period` layers, specialised
per invocation by low-rank (LoRA) adapters on the attention projections
(zamba2-2.7b: 54 mamba layers, shared block with 32 heads / d_ff 10240).

Layer schedule (n_inv = n_layers / period groups):

    for inv in range(n_inv):
        h = shared_attention_block(h, shared_params, lora[inv])   # full attn
        h = scan(mamba_layers[inv*P : (inv+1)*P])                 # SSD

Both levels are lax.scan'd (outer xs = (per-group mamba stacks, per-inv LoRA,
per-inv KV cache)), so HLO stays compact.  Decode keeps one KV cache segment
per invocation plus per-layer SSM states; per-token cost is O(context) for
the shared block and O(1) for the mamba layers -- sub-quadratic overall,
which is why this arch runs the long_500k shape.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.partition import tp_policy
from repro.models.config import ModelConfig

LORA_RANK = 64


def n_invocations(cfg: ModelConfig) -> int:
    assert cfg.shared_attn_period and cfg.n_layers % cfg.shared_attn_period == 0
    return cfg.n_layers // cfg.shared_attn_period


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, km, ksh, kl, kh = jax.random.split(key, 5)
    n_inv = n_invocations(cfg)
    mamba = jax.vmap(lambda k: S.init_mamba_block(k, cfg, dtype))(
        jax.random.split(km, cfg.n_layers)
    )
    # regroup stacked mamba blocks to [n_inv, period, ...]
    period = cfg.shared_attn_period
    mamba = jax.tree_util.tree_map(
        lambda x: x.reshape((n_inv, period) + x.shape[1:]), mamba
    )
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    k1, k2 = jax.random.split(ksh)
    shared = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype, out_scale),
        "mlp": L.init_mlp(k2, cfg, dtype, out_scale),
    }
    d, h, hd, r = cfg.d_model, cfg.n_heads, cfg.head_dim, LORA_RANK
    lk = jax.random.split(kl, 2)
    lora = {
        "a_q": L.dense_init(lk[0], (n_inv, d, r), 1.0 / math.sqrt(d), dtype),
        "b_q": jnp.zeros((n_inv, r, h * hd), dtype),  # zero-init: shared block exact at init
    }
    params = {
        "embed": L.dense_init(ke, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "mamba": mamba,
        "shared": shared,
        "lora": lora,
        "final_ln": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(d), dtype)
    return params


def _shared_attn(cfg, shared, lora_inv, h, positions, cache=None, cache_pos=None):
    """Shared attention + MLP block with per-invocation LoRA on W_q."""
    xn = L.rms_norm(h, shared["ln1"], cfg.rms_eps)
    # LoRA delta on q projection: x @ (Wq + Aq Bq)
    attn_p = dict(shared["attn"])
    attn_p["wq"] = attn_p["wq"] + jnp.einsum(
        "dr,rk->dk", lora_inv["a_q"].astype(jnp.float32), lora_inv["b_q"].astype(jnp.float32)
    ).astype(attn_p["wq"].dtype)
    a, emitted = L.attention_block(
        xn, attn_p, cfg, positions, causal=True, cache=cache, cache_pos=cache_pos
    )
    h = h + a
    h = h + L.mlp_block(L.rms_norm(h, shared["ln2"], cfg.rms_eps), shared["mlp"], cfg)
    return h, emitted


def forward(cfg: ModelConfig, params, tokens, *, remat: bool = True,
            emit_state: bool = False, use_tp=None):
    with tp_policy(cfg.use_tp if use_tp is None else use_tp):
        return _forward_inner(cfg, params, tokens, remat, emit_state)


def _forward_inner(cfg, params, tokens, remat, emit_state):
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def mamba_body(h, lp):
        h2, states = S.mamba_block(h, lp, cfg)
        return h2, states if emit_state else None

    mamba_body = L.remat_wrap(mamba_body, remat)

    def group_body(h, xs):
        lora_inv, mamba_group = xs
        h, kv = _shared_attn(cfg, params["shared"], lora_inv, h, positions)
        h, states = jax.lax.scan(mamba_body, h, mamba_group,
                                 unroll=cfg.shared_attn_period if cfg.scan_unroll else 1)
        return h, (kv, states) if emit_state else None

    if not emit_state:  # remat the whole group: shared-attn intermediates are
        group_body = L.remat_wrap(group_body, remat)  # otherwise saved per group

    h, emitted = jax.lax.scan(group_body, h, (params["lora"], params["mamba"]),
                              unroll=n_invocations(cfg) if cfg.scan_unroll else 1)
    hn = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", hn, w.astype(hn.dtype)).astype(jnp.float32)
    return logits, jnp.float32(0.0), emitted


def init_cache(cfg: ModelConfig, batch: int, cap: int, dtype=jnp.bfloat16) -> dict:
    n_inv = n_invocations(cfg)
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    ssm = S.init_cache(cfg, batch)
    period = cfg.shared_attn_period
    return {
        "attn_k": jnp.zeros((n_inv, batch, cap, kvh, hd), dtype),
        "attn_v": jnp.zeros((n_inv, batch, cap, kvh, hd), dtype),
        "conv": ssm["conv"].reshape((n_inv, period) + ssm["conv"].shape[1:]),
        "ssm": ssm["ssm"].reshape((n_inv, period) + ssm["ssm"].shape[1:]),
    }


def prefill(cfg: ModelConfig, params, tokens, *, cache_cap: Optional[int] = None):
    logits, _, emitted = forward(cfg, params, tokens, remat=False, emit_state=True,
                                 use_tp=cfg.use_tp_serve)
    kv, states = emitted                         # kv: ([I,b,s,kv,hd], [I,...]) tuple
    ks, vs = kv
    conv_tails, ssm_states = states              # [I, P, b, ...]
    s = ks.shape[2]
    cap = cache_cap or s
    if cap > s:
        pad = [(0, 0), (0, 0), (0, cap - s), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {
        "attn_k": ks.astype(jnp.bfloat16),
        "attn_v": vs.astype(jnp.bfloat16),
        "conv": conv_tails,
        "ssm": ssm_states,
    }
    return logits[:, -1, :], cache, jnp.int32(s)


def decode_step(cfg: ModelConfig, params, token, cache, pos):
    with tp_policy(cfg.use_tp_serve):
        return _decode_inner(cfg, params, token, cache, pos)


def _decode_inner(cfg, params, token, cache, pos):
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], token, axis=0).astype(cd)
    b = h.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)

    def mamba_body(h, xs):
        lp, conv_s, ssm_s = xs
        h2, nc, ns = S.mamba_block_decode(h, lp, cfg, conv_s, ssm_s)
        return h2, (nc, ns)

    def group_body(h, xs):
        lora_inv, mamba_group, ck, cv, conv_g, ssm_g = xs
        h, new_kv = _shared_attn(
            cfg, params["shared"], lora_inv, h, positions,
            cache={"k": ck, "v": cv}, cache_pos=pos,
        )
        h, (nconv, nssm) = jax.lax.scan(mamba_body, h, (mamba_group, conv_g, ssm_g),
                                        unroll=cfg.shared_attn_period if cfg.scan_unroll else 1)
        return h, (new_kv["k"], new_kv["v"], nconv, nssm)

    h, (nk, nv, nconv, nssm) = jax.lax.scan(
        group_body, h,
        (params["lora"], params["mamba"], cache["attn_k"], cache["attn_v"],
         cache["conv"], cache["ssm"]),
        unroll=n_invocations(cfg) if cfg.scan_unroll else 1,
    )
    hn = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", hn, w.astype(hn.dtype)).astype(jnp.float32)[:, 0, :]
    return logits, {"attn_k": nk, "attn_v": nv, "conv": nconv, "ssm": nssm}
