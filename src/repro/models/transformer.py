"""Dense / MoE decoder-only transformer (phi3, mistral-large, qwen2.5,
smollm, grok-1, qwen2-moe, and the internvl2 LLM backbone).

Layers are stacked on a leading axis and consumed by lax.scan (one compiled
block body regardless of depth -- keeps dry-run HLO compact at 88 layers) with
optional remat.  The same block code drives train (full sequence), prefill
(emit KV) and decode (cache read/write at position).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import partition
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    out_scale = 1.0 / math.sqrt(2 * cfg.n_layers)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(k1, cfg, dtype, out_scale),
    }
    if cfg.family == "moe":
        p["moe"] = M.init_moe(k2, cfg, dtype, out_scale)
    else:
        p["mlp"] = L.init_mlp(k2, cfg, dtype, out_scale)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg, dtype))(block_keys)
    params = {
        "embed": L.dense_init(ke, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "blocks": blocks,
        "final_ln": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            kh, (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(cfg.d_model), dtype
        )
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block_apply(h, lp, cfg: ModelConfig, positions, *, cache_slice=None, cache_pos=None):
    """One transformer block.  Returns (h, emitted) where emitted is (k, v)
    in full-sequence mode or the updated cache slice in decode mode."""
    # Sequence-parallel residual stream (Megatron-SP): the scan carry / saved
    # remat inputs shard S over "model", cutting per-layer saved activations
    # 16x (mistral-large train: ~141 GB of bf16 carries otherwise).  GSPMD
    # inserts the SP all-gather at attention/MLP entry -- same bytes as the
    # TP all-reduce it replaces.  No-op when S % 16 != 0 or use_tp=False.
    if cache_slice is None:
        h = partition.hint(h, "dp", "tp", None)
    a, emitted = L.attention_block(
        L.rms_norm(h, lp["ln1"], cfg.rms_eps), lp["attn"], cfg, positions,
        causal=True, cache=cache_slice, cache_pos=cache_pos,
    )
    h = h + a
    hn = L.rms_norm(h, lp["ln2"], cfg.rms_eps)
    if cfg.family == "moe":
        m, aux = M.moe_ffn(hn, lp["moe"], cfg)
    else:
        m, aux = L.mlp_block(hn, lp["mlp"], cfg), jnp.float32(0.0)
    return h + m, emitted, aux


def _embed(cfg: ModelConfig, params, tokens, embeds_prefix=None):
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if embeds_prefix is not None:
        h = jnp.concatenate([embeds_prefix.astype(cd), h], axis=1)
    return partition.hint(h, "dp", None, None)


def _head(cfg: ModelConfig, params, h):
    h = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype)).astype(jnp.float32)
    return partition.hint(logits, "dp", None, "tp")


# ---------------------------------------------------------------------------
# Train / full-sequence forward
# ---------------------------------------------------------------------------

def forward(
    cfg: ModelConfig, params, tokens: jnp.ndarray, *,
    embeds_prefix: Optional[jnp.ndarray] = None, remat: bool = True,
    emit_kv: bool = False, use_tp: Optional[bool] = None,
):
    """tokens [B, S] (+ optional prefix embeddings, e.g. image patches) ->
    (logits [B, S_total, V], aux_loss, emitted kv or None)."""
    with partition.tp_policy(cfg.use_tp if use_tp is None else use_tp):
        return _forward_inner(cfg, params, tokens, embeds_prefix, remat, emit_kv)


def _forward_inner(cfg, params, tokens, embeds_prefix, remat, emit_kv):
    h = _embed(cfg, params, tokens, embeds_prefix)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        h, aux = carry
        h2, emitted, aux_l = _block_apply(h, lp, cfg, positions)
        ys = emitted if emit_kv else None
        return (h2, aux + aux_l), ys

    body = L.remat_wrap(body, remat)
    unroll = cfg.n_layers if cfg.scan_unroll else 1
    (h, aux), kv = jax.lax.scan(body, (h, jnp.float32(0.0)), params["blocks"], unroll=unroll)
    return _head(cfg, params, h), aux, kv


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cap: int, dtype=jnp.bfloat16) -> dict:
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (cfg.n_layers, batch, cap, kvh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(cfg: ModelConfig, params, tokens, *, cache_cap: Optional[int] = None,
            embeds_prefix: Optional[jnp.ndarray] = None):
    """Full-sequence forward emitting the KV cache.  Returns (last_logits
    [B, V], cache, pos [])."""
    logits, _, kv = forward(
        cfg, params, tokens, embeds_prefix=embeds_prefix, remat=False, emit_kv=True,
        use_tp=cfg.use_tp_serve,
    )
    ks, vs = kv                                      # [L, B, S, KV, hd]
    s = ks.shape[2]
    cap = cache_cap or s
    if cap > s:
        pad = [(0, 0), (0, 0), (0, cap - s), (0, 0), (0, 0)]
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16)}
    return logits[:, -1, :], cache, jnp.int32(s)


def decode_step(cfg: ModelConfig, params, token: jnp.ndarray, cache: dict, pos: jnp.ndarray):
    """One decode step.  token [B, 1] int32; pos [] int32 (current length).

    Returns (logits [B, V], new_cache).  The cache is functionally updated
    (donate it under jit for in-place aliasing).
    """
    with partition.tp_policy(cfg.use_tp_serve):
        return _decode_inner(cfg, params, token, cache, pos)


def _decode_inner(cfg, params, token, cache, pos):
    h = _embed(cfg, params, token)
    b = h.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)

    def body(h, xs):
        lp, ck, cv = xs
        h2, new_cache, _ = _block_apply(
            h, lp, cfg, positions, cache_slice={"k": ck, "v": cv}, cache_pos=pos
        )
        return h2, (new_cache["k"], new_cache["v"])

    h, (nk, nv) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]),
                               unroll=cfg.n_layers if cfg.scan_unroll else 1)
    logits = _head(cfg, params, h)[:, 0, :]
    return logits, {"k": nk, "v": nv}
