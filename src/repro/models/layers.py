"""Shared transformer layers: RMSNorm, RoPE, GQA attention (online-softmax
chunked for long sequences), SwiGLU/GELU MLPs, embeddings.

All functions are pure (params in, activations out) and layout-stable so the
same code path serves train (full sequence), prefill (full sequence + cache
emit) and decode (single position + cache read/write).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.partition import hint


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def remat_wrap(body, remat):
    """Apply activation checkpointing to a scan body.

    remat: False/"none" -> no remat; "dots" -> save matmul outputs (recompute
    little, +~0.8 GB/layer/device at train_4k); True/"nothing" -> full remat
    (one extra forward, flat memory).  Measured tradeoff in EXPERIMENTS.md
    section Perf, iteration 3.
    """
    if remat in (False, None, "none"):
        return body
    if remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(body, policy=policy)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] (or broadcastable)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                      # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs        # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _soft_cap(scores: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


def full_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool,
    softcap: float = 0.0, q_offset: jnp.ndarray | int = 0,
    kv_len: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Reference attention (materialises [B, H, Sq, Sk] scores).

    q: [B, Sq, H, D];  k, v: [B, Sk, KV, D];  GQA via head grouping.
    q_offset: position of q[0] within the kv axis (decode: current step).
    kv_len: valid kv prefix length (decode with a padded cache).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = _soft_cap(scores * (1.0 / math.sqrt(d)), softcap)
    kv_pos = jnp.arange(sk)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        q_pos = jnp.arange(sq) + q_offset
        scores = jnp.where(q_pos[:, None] >= kv_pos[None, :], scores, neg)
    if kv_len is not None:
        scores = jnp.where(kv_pos[None, :] < kv_len[..., None, None, None, None], scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool,
    softcap: float = 0.0, q_chunk: int = 512, k_chunk: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention: O(chunk^2) live memory for arbitrarily long S.

    Flash-attention restructured for XLA: lax.scan over query chunks, inner
    lax.scan over kv chunks carrying (running max, denominator, accumulator).
    Exact (tested against full_attention).
    """
    b, s, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    if s % q_chunk or sk % k_chunk:
        # fall back for ragged sizes (small models / tests)
        return full_attention(q, k, v, causal=causal, softcap=softcap)
    nq, nk = s // q_chunk, sk // k_chunk
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, nq, q_chunk, kv, g, d).astype(jnp.float32)
    ks = k.reshape(b, nk, k_chunk, kv, d).astype(jnp.float32)
    vs = v.reshape(b, nk, k_chunk, kv, d).astype(jnp.float32)
    q_iota = jnp.arange(q_chunk)
    k_iota = jnp.arange(k_chunk)
    neg = jnp.float32(-1e30)

    # jax.checkpoint: without it, the nested-scan backward saves every
    # per-(q-chunk, kv-chunk) probability tile -- the full S^2 score matrix in
    # f32 (measured: ~46 GB/device at S=4096 on the production mesh).  With
    # it, the backward recomputes each q-chunk's inner scan (flash-attention
    # style) and peak live memory drops to one chunk pair.  EXPERIMENTS.md
    # section Perf, iteration 2.
    @jax.checkpoint
    def q_step(_, qi_qc):
        qi, qc = qi_qc                                       # qc: [b, Cq, kv, g, d]

        def kv_step(carry, kj_kc_vc):
            m, l, acc = carry
            kj, kc, vc = kj_kc_vc                            # kc/vc: [b, Ck, kv, d]
            scores = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc) * scale
            scores = _soft_cap(scores, softcap)
            if causal:
                qpos = qi * q_chunk + q_iota
                kpos = kj * k_chunk + k_iota
                scores = jnp.where(qpos[:, None] >= kpos[None, :], scores, neg)
            m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, q_chunk), neg, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)          # [b, kv, g, Cq, d]
        return None, jnp.transpose(out, (0, 3, 1, 2, 4))      # [b, Cq, kv, g, d]

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d)        # [b, S, H, d]
    return out.astype(q.dtype)


def attention_block(
    x: jnp.ndarray,
    p: dict,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    cache: Optional[dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    kv_override: Optional[tuple] = None,
    long_chunked: bool = True,
):
    """GQA attention with optional KV cache.

    cache: {"k": [B, cap, KV, D], "v": ...} -- when given with cache_pos, the
    new K/V rows are written at cache_pos (decode); attention runs over the
    cache prefix.  Returns (out [B, S, Dm], new_cache or emitted (k, v)).
    kv_override: (k, v) cross-attention memory (encoder output), bypasses
    K/V projection caching.
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    q = hint(q, "dp", None, "tp", None)
    if kv_override is None:
        k = jnp.einsum("bsd,dk->bsk", x, p["wk"].astype(x.dtype)).reshape(b, s, kvh, hd)
        v = jnp.einsum("bsd,dk->bsk", x, p["wv"].astype(x.dtype)).reshape(b, s, kvh, hd)
        k = hint(k, "dp", None, "tp", None)
        v = hint(v, "dp", None, "tp", None)
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(h, hd).astype(x.dtype)
            k = k + p["bk"].reshape(kvh, hd).astype(x.dtype)
            v = v + p["bv"].reshape(kvh, hd).astype(x.dtype)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(h, hd).astype(x.dtype)

    new_cache = None
    if cache is not None:
        # decode / cached attention: write new kv at cache_pos, attend prefix
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        out = full_attention(
            q, ck, cv, causal=False, softcap=cfg.attn_logit_softcap,
            kv_len=cache_pos + s,
        )
        emitted = new_cache
    elif kv_override is not None:
        # cross-attention: chunk long sequences too (a 32k x 32k full score
        # matrix is 68 GB/device on the seamless prefill cell -- measured)
        if long_chunked and s >= 2048 and k.shape[1] >= 2048:
            out = chunked_attention(q, k, v, causal=False, softcap=cfg.attn_logit_softcap)
        else:
            out = full_attention(q, k, v, causal=False, softcap=cfg.attn_logit_softcap)
        emitted = None
    else:
        if long_chunked and s >= 2048:
            out = chunked_attention(q, k, v, causal=causal, softcap=cfg.attn_logit_softcap)
        else:
            out = full_attention(q, k, v, causal=causal, softcap=cfg.attn_logit_softcap)
        emitted = (k, v)
    out = out.reshape(b, s, h * hd)
    proj = jnp.einsum("bsk,kd->bsd", out, p["wo"].astype(x.dtype))
    return hint(proj, "dp", None, None), emitted


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_block(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        gate = hint(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype)), "dp", None, "tp")
        up = hint(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)), "dp", None, "tp")
        out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(gate) * up, p["w_down"].astype(x.dtype))
        return hint(out, "dp", None, None)
    up = hint(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)), "dp", None, "tp")
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(up), p["w_down"].astype(x.dtype))
    return hint(out, "dp", None, None)


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attention(key, cfg: ModelConfig, dtype, out_scale: float) -> dict:
    h, kvh, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), s, dtype),
        "wk": dense_init(ks[1], (d, kvh * hd), s, dtype),
        "wv": dense_init(ks[2], (d, kvh * hd), s, dtype),
        "wo": dense_init(ks[3], (h * hd, d), out_scale / math.sqrt(h * hd), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    return p


def init_mlp(key, cfg: ModelConfig, dtype, out_scale: float, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f), s, dtype),
            "w_up": dense_init(ks[1], (d, f), s, dtype),
            "w_down": dense_init(ks[2], (f, d), out_scale / math.sqrt(f), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), s, dtype),
        "w_down": dense_init(ks[1], (f, d), out_scale / math.sqrt(f), dtype),
    }
