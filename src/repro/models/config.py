"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    mlp_type: str = "swiglu"    # swiglu | gelu
    attn_logit_softcap: float = 0.0

    # --- MoE ---
    n_experts: int = 0
    experts_top_k: int = 0
    moe_d_ff: int = 0           # per routed expert
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_n_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 128

    # --- hybrid (Zamba2) ---
    shared_attn_period: int = 0  # apply the shared attention block every P layers

    # --- modality frontends (stubs: precomputed embeddings) ---
    n_patches: int = 0           # VLM image-patch prefix length
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # sharding policy: small models (<~3B) opt out of tensor parallelism --
    # 16-way TP on a 360M model makes the collective term dominate compute by
    # >10x (measured; EXPERIMENTS.md section Perf) -- and instead use the
    # "model" mesh axis as additional data/FSDP parallelism.
    use_tp: bool = True
    # serving always uses TP: prefill/decode batches (32/128) cannot fill a
    # 256-way DP mesh, and an idle "model" axis means 16x redundant compute
    # (measured: mamba2 prefill useful-FLOPs ratio 0.06; hillclimb B).
    use_tp_serve: bool = True

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # cost-accounting aid: fully unroll layer scans so XLA's HLO cost
    # analysis sees every layer (while-loop bodies are otherwise counted
    # once).  Used by the dry-run's small-L extrapolation, never in training.
    scan_unroll: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        if self.qkv_bias:
            attn += hq + 2 * hkv
        if self.mlp_type == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        norms = 2 * d
        block = attn + mlp + norms

        if self.family == "ssm":
            block = self._ssm_block_params()
        total = self.n_layers * block
        if self.family == "hybrid":
            total = self.n_layers * self._ssm_block_params()
            if self.shared_attn_period:
                total += attn + mlp + 2 * d  # one shared block
        if self.family == "moe":
            routed = 3 * d * self.moe_d_ff * self.n_experts
            shared = 3 * d * self.shared_expert_d_ff if self.shared_expert_d_ff else 0
            router = d * self.n_experts
            block = attn + norms + routed + shared + router
            total = self.n_layers * block
        if self.is_encoder_decoder:
            # encoder blocks + decoder blocks with cross attention
            total = self.n_encoder_layers * block + self.n_layers * (block + attn + d)
        total += v * d                      # embeddings
        if not self.tie_embeddings:
            total += d * v                  # lm head
        total += d                          # final norm
        return total

    def _ssm_block_params(self) -> int:
        d = self.d_model
        din = self.d_inner
        g, n, h = self.ssm_n_groups, self.ssm_state, self.ssm_n_heads
        conv_ch = din + 2 * g * n
        in_proj = d * (2 * din + 2 * g * n + h)
        return in_proj + conv_ch * self.conv_width + 3 * h + din + din * d + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        routed_active = 3 * d * self.moe_d_ff * self.experts_top_k
        shared = 3 * d * self.shared_expert_d_ff if self.shared_expert_d_ff else 0
        router = d * self.n_experts
        block = attn + 2 * d + routed_active + shared + router
        total = self.n_layers * block + self.vocab * self.d_model
        if not self.tie_embeddings:
            total += self.d_model * self.vocab
        return total
