from repro.models import config, encdec, hybrid, layers, moe, registry, ssm, transformer  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
