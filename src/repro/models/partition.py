"""Activation-sharding hints.

`hint(x, roles...)` applies a with_sharding_constraint built from logical dim
roles, resolved against the ambient abstract mesh (jax.sharding.set_mesh):

    "dp"  -> batch-like dim over ("pod", "data") (whichever exist)
    "tp"  -> feature-like dim over "model"
    None  -> unsharded

Each role is applied only when the dim size divides the axis size -- the same
degrade-per-tensor policy as launch/sharding.py.  Outside a mesh context the
function is a no-op, so model code runs unchanged in single-device tests.

These hints exist because GSPMD propagation alone replicated the vocab dim of
the logits (and the d_ff dim of MLP activations) on the production mesh,
blowing per-device temp memory by ~25x -- measured in the dry-run and recorded
as perf iteration 1 in EXPERIMENTS.md section Perf.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

# Per-trace policy: archs with use_tp=False treat the "model" axis as extra
# data parallelism (see ModelConfig.use_tp).  Set by the family forward
# functions around their trace bodies.
_USE_TP = contextvars.ContextVar("repro_use_tp", default=True)


@contextlib.contextmanager
def tp_policy(use_tp: bool):
    tok = _USE_TP.set(use_tp)
    try:
        yield
    finally:
        _USE_TP.reset(tok)


def _mesh_axes() -> Optional[dict]:
    try:
        am = jax.sharding.get_abstract_mesh()
    except (AttributeError, RuntimeError):
        # AttributeError: this jax predates get_abstract_mesh (the live path
        # on 0.4.x); RuntimeError: no mesh context is active.  Either way
        # there is no mesh to partition over -- fall back to replicated.
        return None
    names = getattr(am, "axis_names", ())
    if not names:
        return None
    sizes = getattr(am, "axis_sizes", None)
    if sizes is None:
        shape = getattr(am, "shape", {})
        sizes = tuple(shape[n] for n in names)
    return dict(zip(names, sizes))


def _resolve(role: Optional[str], dim: int, axes: dict):
    use_tp = _USE_TP.get()
    if role == "dp":
        names = ("pod", "data") if use_tp else ("pod", "data", "model")
        base = tuple(a for a in names if a in axes)
        # contiguous subsets, largest first (see launch/sharding.py note)
        cands = [base[i:j] for i in range(len(base)) for j in range(len(base), i, -1)]
        cands.sort(key=lambda c: -math.prod(axes[a] for a in c))
        for cand in cands:
            total = math.prod(axes[a] for a in cand)
            if dim % total == 0:
                return cand if len(cand) > 1 else cand[0]
        return None
    if role == "tp":
        if use_tp and "model" in axes and dim % axes["model"] == 0:
            return "model"
        return None
    if role == "dpt":  # full-mesh shard (DP axes + model together)
        cand = tuple(a for a in ("pod", "data", "model") if a in axes)
        while cand:
            total = math.prod(axes[a] for a in cand)
            if dim % total == 0:
                return cand if len(cand) > 1 else cand[0]
            cand = cand[:-1]
        return None
    if role == "rep":  # explicitly replicated (forces an FSDP weight gather)
        return None
    return None


def hint(x, *roles):
    """Constrain x's sharding by per-dim logical roles (no-op without mesh)."""
    axes = _mesh_axes()
    if axes is None:
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    spec = P(*(_resolve(r, d, axes) for r, d in zip(roles, x.shape)))
    return jax.lax.with_sharding_constraint(x, spec)
