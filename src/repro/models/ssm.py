"""Mamba2 (SSD -- state-space duality) blocks: chunked parallel scan for
train/prefill, O(1)-state recurrence for decode.  (mamba2-1.3b and the
zamba2 backbone.)

SSD recurrence per head (state S in R^{n x p}, decay a_t <= 0):

    S_t = exp(a_t) S_{t-1} + dt_t B_t (x_t dt-weighted outer product)
    y_t = C_t . S_t + D x_t

Chunked algorithm (Dao & Gu 2024): within a chunk of length Lc the
contribution of x_j to y_i (j <= i) is C_i.B_j exp(cum_i - cum_j) dt_j x_j --
an attention-like [Lc, Lc] matmul on the MXU; across chunks only the [n, p]
states are carried by a lax.scan.  Sequence length cost is O(S * Lc) instead
of O(S^2): this is what makes the long_500k shape feasible and is validated
against the naive recurrence in tests/test_ssm.py.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.partition import hint, tp_policy


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_n_groups * cfg.ssm_state


def init_mamba_block(key, cfg: ModelConfig, dtype) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads
    cch = conv_channels(cfg)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    proj_out = 2 * din + 2 * g * n + h
    return {
        "ln": jnp.ones((d,), dtype),
        "in_proj": L.dense_init(ks[0], (d, proj_out), s, dtype),
        "conv_w": L.dense_init(ks[1], (cfg.conv_width, cch), 1.0 / math.sqrt(cfg.conv_width), dtype),
        "conv_b": jnp.zeros((cch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), math.log(math.e - 1.0), jnp.float32),  # softplus^-1(1)
        "norm": jnp.ones((din,), dtype),
        "out_proj": L.dense_init(ks[2], (din, d), 1.0 / math.sqrt(2 * cfg.n_layers * din), dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kb, kh = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(
        jax.random.split(kb, cfg.n_layers)
    )
    params = {
        "embed": L.dense_init(ke, (cfg.vocab, cfg.d_model), 0.02, dtype),
        "blocks": blocks,
        "final_ln": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(
    x: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
    Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
    init_state: Optional[jnp.ndarray] = None,
):
    """x [b,s,h,p]; dt [b,s,h] (post-softplus); A_log [h]; Bm/Cm [b,s,g,n].

    Returns (y [b,s,h,p], final_state [b,h,n,p]).
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hg = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    f32 = jnp.float32
    a = (-jnp.exp(A_log.astype(f32)) * dt.astype(f32))               # [b,s,h]
    xd = x.astype(f32) * dt.astype(f32)[..., None]                   # [b,s,h,p]

    a_c = jnp.moveaxis(a.reshape(b, nc, chunk, h), 3, 2)             # [b,c,h,l]
    cum = jnp.cumsum(a_c, axis=-1)                                   # [b,c,h,l]
    B_c = Bm.astype(f32).reshape(b, nc, chunk, g, n)
    C_c = Cm.astype(f32).reshape(b, nc, chunk, g, n)
    x_c = xd.reshape(b, nc, chunk, h, p)

    # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) xd_j
    CB = jnp.einsum("bcign,bcjgn->bcgij", C_c, B_c)                  # [b,c,g,l,l]
    CB = jnp.repeat(CB, hg, axis=2)                                  # [b,c,h,l,l]
    diff = cum[..., :, None] - cum[..., None, :]                     # [b,c,h,i,j]
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: the upper triangle has positive exponents that
    # overflow to inf, and where(tril, inf, 0) still propagates NaN grads.
    decay = jnp.where(tril, jnp.exp(jnp.where(tril, diff, 0.0)), 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", CB * decay, x_c)      # [b,c,l,h,p]

    # per-chunk state contribution: S_c = sum_j B_j (x)_j exp(cum_end - cum_j)
    w_end = jnp.exp(cum[..., -1:] - cum)                             # [b,c,h,l]
    B_h = jnp.repeat(B_c, hg, axis=3).reshape(b, nc, chunk, h, n)    # group->head
    S_c = jnp.einsum("bclhn,bclhp,bchl->bchnp", B_h, x_c, w_end)     # [b,c,h,n,p]

    chunk_decay = jnp.exp(cum[..., -1])                              # [b,c,h]

    def step(S_prev, xs):
        cd, Sc = xs                                                  # [b,h], [b,h,n,p]
        S_out = S_prev
        S_next = S_prev * cd[..., None, None] + Sc
        return S_next, S_out

    S0 = init_state.astype(f32) if init_state is not None else jnp.zeros((b, h, n, p), f32)
    S_final, S_in = jax.lax.scan(
        step, S0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0))
    )
    S_in = jnp.moveaxis(S_in, 0, 1)                                  # [b,c,h,n,p]

    # inter-chunk: y_l += C_l . (S_in decayed to l) = C_l.S_in * exp(cum_l)
    C_h = jnp.repeat(C_c, hg, axis=3).reshape(b, nc, chunk, h, n)
    y_inter = jnp.einsum("bclhn,bchnp,bchl->bclhp", C_h, S_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), S_final


def ssd_decode(
    x: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
    Bm: jnp.ndarray, Cm: jnp.ndarray, state: jnp.ndarray,
):
    """Single-step recurrence.  x [b,h,p]; dt [b,h]; Bm/Cm [b,g,n];
    state [b,h,n,p] -> (y [b,h,p], new_state)."""
    h = x.shape[1]
    hg = h // Bm.shape[1]
    f32 = jnp.float32
    a = jnp.exp(-jnp.exp(A_log.astype(f32)) * dt.astype(f32))        # [b,h]
    B_h = jnp.repeat(Bm.astype(f32), hg, axis=1)                     # [b,h,n]
    C_h = jnp.repeat(Cm.astype(f32), hg, axis=1)
    xd = x.astype(f32) * dt.astype(f32)[..., None]                   # [b,h,p]
    new_state = state * a[..., None, None] + B_h[..., None] * xd[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", C_h, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """xbc [b, s, ch]; w [W, ch] depthwise causal conv; silu activation."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):  # static, width=4
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)


def conv_decode(xbc: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray):
    """xbc [b, ch] single step; conv_state [b, W-1, ch] (previous inputs).

    Returns (activated [b, ch], new_conv_state)."""
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [b, W, ch]
    out = jnp.sum(window.astype(jnp.float32) * w.astype(jnp.float32)[None], axis=1)
    y = jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Block apply (full sequence / decode)
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    din, g, n, h = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads
    z = proj[..., :din]
    xbc = proj[..., din : 2 * din + 2 * g * n]
    dt = proj[..., 2 * din + 2 * g * n :]
    return z, xbc, dt


def mamba_block(h: jnp.ndarray, lp: dict, cfg: ModelConfig,
                init_state: Optional[jnp.ndarray] = None):
    """Full-sequence Mamba2 block.  Returns (h_out, (conv_tail, ssm_state))."""
    b, s, _ = h.shape
    din, g, n, nh, p = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    xn = L.rms_norm(h, lp["ln"], cfg.rms_eps)
    proj = jnp.einsum("bsd,dk->bsk", xn, lp["in_proj"].astype(xn.dtype))
    proj = hint(proj, "dp", None, None)
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    xbc = causal_conv(xbc_raw, lp["conv_w"], lp["conv_b"])
    x = hint(xbc[..., :din].reshape(b, s, nh, p), "dp", None, "tp", None)
    Bm = xbc[..., din : din + g * n].reshape(b, s, g, n)
    Cm = xbc[..., din + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    y, state = ssd_chunked(x, dt, lp["A_log"], Bm, Cm, cfg.ssd_chunk, init_state)
    y = y + x * lp["D_skip"].astype(jnp.float32)[None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, din)
    y = L.rms_norm(y, lp["norm"], cfg.rms_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, lp["out_proj"].astype(y.dtype))
    out = hint(out, "dp", None, None)
    conv_tail = xbc_raw[:, -(cfg.conv_width - 1):, :]   # pre-conv inputs for decode
    return h + out, (conv_tail, state)


def mamba_block_decode(h: jnp.ndarray, lp: dict, cfg: ModelConfig,
                       conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Single-token Mamba2 block.  h [b, 1, d]."""
    b = h.shape[0]
    din, g, n, nh, p = cfg.d_inner, cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    xn = L.rms_norm(h, lp["ln"], cfg.rms_eps)[:, 0, :]
    proj = jnp.einsum("bd,dk->bk", xn, lp["in_proj"].astype(xn.dtype))
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    xbc, new_conv = conv_decode(xbc_raw, conv_state, lp["conv_w"], lp["conv_b"])
    x = xbc[..., :din].reshape(b, nh, p)
    Bm = xbc[..., din : din + g * n].reshape(b, g, n)
    Cm = xbc[..., din + g * n :].reshape(b, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    y, new_state = ssd_decode(x, dt, lp["A_log"], Bm, Cm, ssm_state)
    y = y + x * lp["D_skip"].astype(jnp.float32)[None, :, None].astype(x.dtype)
    y = y.reshape(b, din)
    y = L.rms_norm(y, lp["norm"], cfg.rms_eps) * jax.nn.silu(z)
    out = jnp.einsum("bk,kd->bd", y, lp["out_proj"].astype(y.dtype))
    return h + out[:, None, :], new_conv, new_state


# ---------------------------------------------------------------------------
# Model-level API (matches transformer.py's surface)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, *, remat: bool = True,
            emit_state: bool = False, use_tp=None):
    with tp_policy(cfg.use_tp if use_tp is None else use_tp):
        return _forward_inner(cfg, params, tokens, remat, emit_state)


def _forward_inner(cfg, params, tokens, remat, emit_state):
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cd)

    def body(h, lp):
        h2, states = mamba_block(h, lp, cfg)
        return h2, states if emit_state else None

    body = L.remat_wrap(body, remat)
    unroll = cfg.n_layers if cfg.scan_unroll else 1
    h, states = jax.lax.scan(body, h, params["blocks"], unroll=unroll)
    hn = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", hn, w.astype(hn.dtype)).astype(jnp.float32)
    return logits, jnp.float32(0.0), states


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    cch = conv_channels(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, cch), dtype),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_n_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
    }


def prefill(cfg: ModelConfig, params, tokens):
    logits, _, states = forward(cfg, params, tokens, remat=False, emit_state=True,
                                use_tp=cfg.use_tp_serve)
    conv_tails, ssm_states = states                  # [L, b, W-1, cch], [L, b, h, n, p]
    cache = {"conv": conv_tails, "ssm": ssm_states}
    return logits[:, -1, :], cache, jnp.int32(tokens.shape[1])


def decode_step(cfg: ModelConfig, params, token, cache, pos):
    with tp_policy(cfg.use_tp_serve):
        return _decode_inner(cfg, params, token, cache, pos)


def _decode_inner(cfg, params, token, cache, pos):
    cd = L.cdtype(cfg)
    h = jnp.take(params["embed"], token, axis=0).astype(cd)

    def body(h, xs):
        lp, conv_s, ssm_s = xs
        h2, nc, ns = mamba_block_decode(h, lp, cfg, conv_s, ssm_s)
        return h2, (nc, ns)

    h, (nconv, nssm) = jax.lax.scan(body, h, (params["blocks"], cache["conv"], cache["ssm"]),
                                    unroll=cfg.n_layers if cfg.scan_unroll else 1)
    hn = L.rms_norm(h, params["final_ln"], cfg.rms_eps)
    w = params["lm_head"] if not cfg.tie_embeddings else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", hn, w.astype(hn.dtype)).astype(jnp.float32)[:, 0, :]
    return logits, {"conv": nconv, "ssm": nssm}
