"""Uniform model API over the six architecture families.

Every family exposes:

    init_params(cfg, key)                          -> params pytree
    train_logits(cfg, params, batch, remat=True)   -> (logits, aux, labels)
    prefill(cfg, params, batch, cache_cap)         -> (last_logits, cache, pos)
    decode_step(cfg, params, token, cache, pos)    -> (logits, cache)

`batch` is a dict:
    dense / ssm / hybrid / moe : {"tokens": [B, S]}
    vlm   : {"patch_embeds": [B, P, D], "tokens": [B, S-P]}   (frontend stub)
    audio : {"frames": [B, S, D], "tokens": [B, S]}           (frontend stub)

Labels are next-token shifts of the text tokens (modality prefixes excluded
from the loss).  configs/ registers one ModelConfig per --arch id.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer
from repro.models.config import ModelConfig

IGNORE = -100  # label id excluded from the loss


def _shift_labels(tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate(
        [tokens[:, 1:], jnp.full((tokens.shape[0], 1), IGNORE, tokens.dtype)], axis=1
    )


@dataclasses.dataclass(frozen=True)
class ModelApi:
    family: str
    init_params: Callable
    train_logits: Callable      # (cfg, params, batch, remat) -> (logits, aux, labels)
    prefill: Callable           # (cfg, params, batch, cache_cap) -> (logits, cache, pos)
    decode_step: Callable       # (cfg, params, token, cache, pos) -> (logits, cache)
    supports_decode: bool = True
    sub_quadratic: bool = False


# --- dense / moe -----------------------------------------------------------

def _lm_train(cfg, params, batch, remat=True):
    logits, aux, _ = transformer.forward(cfg, params, batch["tokens"], remat=remat)
    return logits, aux, _shift_labels(batch["tokens"])


def _lm_prefill(cfg, params, batch, cache_cap=None):
    return transformer.prefill(cfg, params, batch["tokens"], cache_cap=cache_cap)


_DENSE = ModelApi("dense", transformer.init_params, _lm_train, _lm_prefill,
                  transformer.decode_step)
_MOE = dataclasses.replace(_DENSE, family="moe")


# --- ssm -------------------------------------------------------------------

def _ssm_train(cfg, params, batch, remat=True):
    logits, aux, _ = ssm.forward(cfg, params, batch["tokens"], remat=remat)
    return logits, aux, _shift_labels(batch["tokens"])


def _ssm_prefill(cfg, params, batch, cache_cap=None):
    return ssm.prefill(cfg, params, batch["tokens"])


_SSM = ModelApi("ssm", ssm.init_params, _ssm_train, _ssm_prefill, ssm.decode_step,
                sub_quadratic=True)


# --- hybrid ----------------------------------------------------------------

def _hyb_train(cfg, params, batch, remat=True):
    logits, aux, _ = hybrid.forward(cfg, params, batch["tokens"], remat=remat)
    return logits, aux, _shift_labels(batch["tokens"])


def _hyb_prefill(cfg, params, batch, cache_cap=None):
    return hybrid.prefill(cfg, params, batch["tokens"], cache_cap=cache_cap)


_HYBRID = ModelApi("hybrid", hybrid.init_params, _hyb_train, _hyb_prefill,
                   hybrid.decode_step, sub_quadratic=True)


# --- vlm (internvl2: patch-embedding prefix + dense LLM backbone) ----------

def _vlm_train(cfg, params, batch, remat=True):
    logits, aux, _ = transformer.forward(
        cfg, params, batch["tokens"], embeds_prefix=batch["patch_embeds"], remat=remat
    )
    p = batch["patch_embeds"].shape[1]
    text_labels = _shift_labels(batch["tokens"])
    labels = jnp.concatenate(
        [jnp.full((text_labels.shape[0], p), IGNORE, text_labels.dtype), text_labels], axis=1
    )
    return logits, aux, labels


def _vlm_prefill(cfg, params, batch, cache_cap=None):
    return transformer.prefill(
        cfg, params, batch["tokens"], cache_cap=cache_cap,
        embeds_prefix=batch["patch_embeds"],
    )


_VLM = ModelApi("vlm", transformer.init_params, _vlm_train, _vlm_prefill,
                transformer.decode_step)


# --- audio (seamless enc-dec) ----------------------------------------------

def _audio_train(cfg, params, batch, remat=True):
    logits, aux, _ = encdec.forward(cfg, params, batch["frames"], batch["tokens"], remat=remat)
    return logits, aux, _shift_labels(batch["tokens"])


def _audio_prefill(cfg, params, batch, cache_cap=None):
    return encdec.prefill(cfg, params, batch["frames"], batch["tokens"], cache_cap=cache_cap)


_AUDIO = ModelApi("audio", encdec.init_params, _audio_train, _audio_prefill,
                  encdec.decode_step)


_FAMILIES = {
    "dense": _DENSE,
    "moe": _MOE,
    "ssm": _SSM,
    "hybrid": _HYBRID,
    "vlm": _VLM,
    "audio": _AUDIO,
}

_CONFIGS: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _CONFIGS[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if not _CONFIGS:
        import repro.configs  # noqa: F401  (populates the registry)
    return _CONFIGS[arch_id]


def get_api(cfg: ModelConfig) -> ModelApi:
    return _FAMILIES[cfg.family]


def list_archs() -> list[str]:
    if not _CONFIGS:
        import repro.configs  # noqa: F401
    return sorted(_CONFIGS)
