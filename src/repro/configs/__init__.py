"""Assigned-architecture configs.  Importing this package registers every
--arch id (full config + "<id>-smoke" reduced variant) with models.registry.
"""
from repro.configs import (  # noqa: F401
    genie_datasets,
    grok_1_314b,
    internvl2_76b,
    mamba2_1_3b,
    mistral_large_123b,
    phi3_mini_3_8b,
    qwen2_5_14b,
    qwen2_moe_a2_7b,
    seamless_m4t_large_v2,
    smollm_360m,
    zamba2_2_7b,
)

ALL_ARCHS = [
    "phi3-mini-3.8b",
    "mistral-large-123b",
    "qwen2.5-14b",
    "smollm-360m",
    "mamba2-1.3b",
    "zamba2-2.7b",
    "qwen2-moe-a2.7b",
    "grok-1-314b",
    "internvl2-76b",
    "seamless-m4t-large-v2",
]
