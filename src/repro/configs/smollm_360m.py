"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
-- llama-arch small, tied embeddings.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=49152, tie_embeddings=True, rope_theta=10_000.0,    use_tp=False,
))

SMOKE = register(ModelConfig(
    arch_id="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
    d_ff=160, vocab=512, tie_embeddings=True, rope_theta=10_000.0,
))
