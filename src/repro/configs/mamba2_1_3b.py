"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 4096, head_dim 64 -> 64 SSD heads, 1 B/C group.
"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_n_groups=1,
    conv_width=4, ssd_chunk=256,    use_tp=False,
))

SMOKE = register(ModelConfig(
    arch_id="mamba2-1.3b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=512, tie_embeddings=True,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_n_groups=1,
    conv_width=4, ssd_chunk=8,
))
