"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) expert d_ff=32768
vocab=131072, MoE 8 experts top-2, attention logit softcap 30.
[hf:xai-org/grok-1; unverified]"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab=131072, attn_logit_softcap=30.0,
    n_experts=8, experts_top_k=2, moe_d_ff=32768, shared_expert_d_ff=0,
    capacity_factor=1.25,
))

SMOKE = register(ModelConfig(
    arch_id="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=0, vocab=512, attn_logit_softcap=30.0,
    n_experts=4, experts_top_k=2, moe_d_ff=128, shared_expert_d_ff=0,
    capacity_factor=1.25,
))
