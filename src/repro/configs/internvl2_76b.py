"""internvl2-76b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 -- InternViT + InternLM2 backbone.  [arXiv:2404.16821;
unverified]

The InternViT frontend is a STUB: input_specs feeds precomputed patch
embeddings [B, 256, d_model]; the 80-layer LLM backbone is fully built.
"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, rope_theta=1_000_000.0,
    n_patches=256,
))

SMOKE = register(ModelConfig(
    arch_id="internvl2-76b-smoke", family="vlm",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab=512, rope_theta=1_000_000.0,
    n_patches=8,
))
