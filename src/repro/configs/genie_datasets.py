"""GENIE dataset configurations mirroring the paper's five experiments
(section VI-A1), with synthetic stand-ins sized for this container and
full-scale shapes used by the dry-run / roofline.

    OCR        3.5M x 1156-dim points, RBH (Laplacian kernel), rehash to 8192
    SIFT       4.5M x 128-dim points, E2LSH (l2), 67 buckets
    SIFT_LARGE 36M SIFT features (multi-loading)
    DBLP       5.0M title sequences, 3-grams, K=32 candidates
    Tweets     6.8M short documents, word vectors
    Adult      0.98M tuples x 14 attributes, 1024 bins, range +-50
"""
from __future__ import annotations

import dataclasses

from repro.core.lsh import tau_ann


@dataclasses.dataclass(frozen=True)
class GenieDatasetConfig:
    name: str
    engine: str            # eq | minsum | ip | range
    n_objects: int         # full-scale (dry-run / roofline)
    n_objects_bench: int   # reduced (CPU benchmarks)
    dim: int               # raw dimensionality / #attributes
    m: int                 # hash functions (EQ) or vocab buckets (minsum/ip)
    n_buckets: int         # rehash domain D
    default_k: int = 100
    queries_per_batch: int = 1024
    extra: tuple = ()


EPS = DELTA = 0.06
M_PRACTICAL = 237          # paper Fig 8 (our binomial computation gives 238; see EXPERIMENTS.md)


def m_paper() -> int:
    return M_PRACTICAL


DATASETS = {
    "ocr": GenieDatasetConfig(
        name="ocr", engine="eq", n_objects=3_500_000, n_objects_bench=20_000,
        dim=1156, m=M_PRACTICAL, n_buckets=8192,
    ),
    "sift": GenieDatasetConfig(
        name="sift", engine="eq", n_objects=4_500_000, n_objects_bench=20_000,
        dim=128, m=M_PRACTICAL, n_buckets=67,
    ),
    "sift_large": GenieDatasetConfig(
        name="sift_large", engine="eq", n_objects=36_000_000, n_objects_bench=60_000,
        dim=128, m=M_PRACTICAL, n_buckets=67,
    ),
    "dblp": GenieDatasetConfig(
        name="dblp", engine="minsum", n_objects=5_000_000, n_objects_bench=20_000,
        dim=40, m=4096, n_buckets=4096, default_k=1,
    ),
    "tweets": GenieDatasetConfig(
        name="tweets", engine="ip", n_objects=6_800_000, n_objects_bench=20_000,
        dim=16, m=8192, n_buckets=8192,
    ),
    "adult": GenieDatasetConfig(
        name="adult", engine="range", n_objects=980_000, n_objects_bench=20_000,
        dim=14, m=14, n_buckets=1024,
    ),
}
