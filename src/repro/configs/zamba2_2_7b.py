"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 -- Mamba2 backbone + weight-shared attention
blocks (every 6 layers, per-invocation LoRA).  [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_n_groups=1,
    conv_width=4, ssd_chunk=256, shared_attn_period=6,    use_tp=False,
))

SMOKE = register(ModelConfig(
    arch_id="zamba2-2.7b-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab=512,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_n_groups=1,
    conv_width=4, ssd_chunk=8, shared_attn_period=2,
))
