"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206, GELU FFN.
[arXiv:2308.11596; hf]

The speech frontend is a STUB: input_specs feeds precomputed frame
embeddings [B, S, d_model]; encoder/decoder backbones are fully built.
"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="seamless-m4t-large-v2", family="audio",
    n_layers=24, n_encoder_layers=24, is_encoder_decoder=True,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, mlp_type="gelu",
))

SMOKE = register(ModelConfig(
    arch_id="seamless-m4t-large-v2-smoke", family="audio",
    n_layers=2, n_encoder_layers=2, is_encoder_decoder=True,
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=192, vocab=512, mlp_type="gelu",
))
