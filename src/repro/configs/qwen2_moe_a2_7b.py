"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) routed-expert
d_ff=1408 vocab=151936, MoE 60 experts top-4 + 4 shared experts (shared
intermediate 4*1408=5632, sigmoid-gated).  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.config import ModelConfig
from repro.models.registry import register

FULL = register(ModelConfig(
    arch_id="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab=151936, qkv_bias=True,
    n_experts=60, experts_top_k=4, moe_d_ff=1408, shared_expert_d_ff=5632,
    capacity_factor=1.25,
))

SMOKE = register(ModelConfig(
    arch_id="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab=512, qkv_bias=True,
    n_experts=8, experts_top_k=2, moe_d_ff=48, shared_expert_d_ff=96,
    capacity_factor=1.25,
))
