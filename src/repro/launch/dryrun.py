import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell and both production meshes
(16x16 single-pod, 2x16x16 multi-pod), lower + compile the appropriate step
function with full-size ShapeDtypeStruct inputs (no allocation), then record:

  * memory_analysis()  -- per-device argument/output/temp bytes (fit proof)
  * cost_analysis()    -- per-device HLO FLOPs / bytes accessed
  * collective bytes   -- parsed from the optimized HLO, by collective type
  * MODEL_FLOPS        -- analytic 6*N*D (train) / 2*N_active*D (inference)

plus the GENIE search_step cells (paper-scale index shapes, objects sharded
over the full mesh).  Results go to reports/dryrun/<cell>.json, one file per
cell, resumable.  Any sharding mismatch / unsupported collective / compile
OOM here is a bug in the system (and several were found and fixed this way).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --genie --mesh single
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh_lib
from repro.launch import shapes as shapes_lib
from repro.models.registry import get_api, get_config
from repro.train import step as train_step_lib

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "reports", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in optimized HLO,
    grouped by op kind.  '-done' halves of async pairs are skipped."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        result_part = line.split("=", 1)[1].split(m.group(1))[0]
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_part))
        out[op] = out.get(op, 0) + total
    return out


def _mem_dict(mem) -> dict:
    keys = [
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ]
    return {k: int(getattr(mem, k)) for k in keys if hasattr(mem, k)}


def _cost_dict(cost) -> dict:
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
        if k in cost:
            out[k.replace(" ", "_")] = float(cost[k])
    return out


def _report(lowered, compiled, seconds: float) -> dict:
    txt = compiled.as_text()
    cost = compiled.cost_analysis()
    return dict(
        ok=True,
        compile_seconds=round(seconds, 2),
        memory=_mem_dict(compiled.memory_analysis()),
        cost=_cost_dict(cost),
        collectives=collective_bytes(txt),
        hlo_ops=len(txt.splitlines()),
    )


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lower_lm(cfg, shape, mesh, accum_override=None):
    """Lower + compile the step function for one (cfg, shape) on `mesh`."""
    api = get_api(cfg)
    # training uses the per-arch DP/TP choice; serving always uses TP
    use_tp = cfg.use_tp if shape.kind == "train" else cfg.use_tp_serve
    with mesh_lib.use_mesh(mesh):
        batch_sds = shapes_lib.input_specs(cfg, shape)
        batch_sh = sh_lib.batch_shardings(batch_sds, mesh, use_tp)
        params_shapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
        params_sh = sh_lib.params_shardings(params_shapes, mesh, use_tp)

        if shape.kind == "train":
            # microbatch accumulation sized so each microbatch holds <=8k
            # tokens per device (the standard pod-scale recipe; saved scan
            # carries and logits scale down by `accum`): iteration 6.
            dp = mesh_lib.dp_size(mesh) * (1 if use_tp else mesh_lib.tp_size(mesh))
            tokens_per_dev = shape.global_batch * shape.seq_len // dp
            accum = 1
            while tokens_per_dev // accum > 8192 and shape.global_batch % (2 * accum) == 0:
                accum *= 2
            if accum_override is not None:
                accum = accum_override
            # bf16 Adam moments for >100B models: f32 moments alone exceed
            # 16 GB/chip at 256 chips for grok-1 (EXPERIMENTS.md Perf iter 7)
            from repro.optim.adamw import AdamWConfig

            mdt = "bfloat16" if cfg.param_count() > 100e9 else "float32"
            hp = train_step_lib.TrainHParams(
                accum=accum, optimizer=AdamWConfig(moment_dtype=mdt))
            step_fn = train_step_lib.make_train_step(cfg, api, hp)
            state_sds = jax.eval_shape(
                lambda: train_step_lib.init_state(cfg, api, jax.random.PRNGKey(0), hp)
            )
            state_sh = sh_lib.state_shardings(state_sds, params_sh, mesh)
            out_sds = jax.eval_shape(step_fn, state_sds, batch_sds)
            metrics_sh = jax.tree_util.tree_map(lambda _: sh_lib.replicated(mesh), out_sds[1])
            jitted = jax.jit(
                step_fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh), donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, batch_sds)

        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return api.prefill(cfg, params, batch, cache_cap=shape.seq_len)

            jitted = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_shapes, batch_sds)

        else:  # decode
            cache_sds = shapes_lib.cache_specs(cfg, shape)
            cache_sh = sh_lib.cache_shardings(cfg, cache_sds, mesh)
            token_sds = shapes_lib.token_specs(cfg, shape)
            token_sh = sh_lib.batch_shardings({"t": token_sds}, mesh, use_tp)["t"]
            logits_sds = jax.ShapeDtypeStruct((shape.global_batch, cfg.vocab), jnp.float32)
            logits_sh = sh_lib.batch_shardings({"l": logits_sds}, mesh, use_tp)["l"]

            def decode_fn(params, token, cache, pos):
                return api.decode_step(cfg, params, token, cache, pos)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(params_sh, token_sh, cache_sh, sh_lib.replicated(mesh)),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                params_shapes, token_sds, cache_sds,
                jax.ShapeDtypeStruct((), jnp.int32),
            )

        compiled = lowered.compile()
    return lowered, compiled


def _layer_variants(cfg):
    """(cfg_1unit, cfg_2unit, n_units) for the unrolled cost extrapolation."""
    import dataclasses as dc

    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        return (
            dc.replace(cfg, n_layers=p, scan_unroll=True),
            dc.replace(cfg, n_layers=2 * p, scan_unroll=True),
            cfg.n_layers // p,
        )
    if cfg.family == "audio":
        return (
            dc.replace(cfg, n_layers=1, n_encoder_layers=1, scan_unroll=True),
            dc.replace(cfg, n_layers=2, n_encoder_layers=2, scan_unroll=True),
            cfg.n_layers,  # == n_encoder_layers for seamless
        )
    return (
        dc.replace(cfg, n_layers=1, scan_unroll=True),
        dc.replace(cfg, n_layers=2, scan_unroll=True),
        cfg.n_layers,
    )


def _extrapolated_costs(cfg, shape, mesh) -> dict:
    """HLO FLOPs / bytes / collectives at full depth, from two unrolled
    small-depth compiles.

    XLA's cost analysis counts a while-loop body ONCE regardless of trip
    count, so the scanned production program under-reports per-layer work.
    We lower the same cell with 1 and 2 layer-units, scans fully unrolled
    (no while loops), and extrapolate linearly:
        cost(L) = cost(1) + (L - 1) * (cost(2) - cost(1)).
    Exact for layer-homogeneous programs (all of ours are).
    """
    cfg1, cfg2, units = _layer_variants(cfg)
    # accum=1 for the cost variants: the accumulation lax.scan body would be
    # counted once by cost analysis (total FLOPs are accum-invariant anyway).
    _, comp1 = _lower_lm(cfg1, shape, mesh, accum_override=1)
    c1, coll1 = _cost_dict(comp1.cost_analysis()), collective_bytes(comp1.as_text())
    _, comp2 = _lower_lm(cfg2, shape, mesh, accum_override=1)
    c2, coll2 = _cost_dict(comp2.cost_analysis()), collective_bytes(comp2.as_text())
    ex_cost = {
        k: c1.get(k, 0.0) + (units - 1) * (c2.get(k, 0.0) - c1.get(k, 0.0))
        for k in set(c1) | set(c2)
    }
    ex_coll = {
        k: int(coll1.get(k, 0) + (units - 1) * (coll2.get(k, 0) - coll1.get(k, 0)))
        for k in set(coll1) | set(coll2)
    }
    return dict(cost=ex_cost, collectives=ex_coll, units=units,
                base=dict(cost=c1, collectives=coll1))


def run_lm_cell(arch: str, shape_name: str, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    api = get_api(cfg)
    shape = shapes_lib.SHAPES[shape_name]
    supported, reason = shapes_lib.cell_supported(cfg, shape)
    if not supported:
        return dict(ok=True, skipped=True, reason=reason)
    if shape.kind == "decode" and not api.supports_decode:
        return dict(ok=True, skipped=True, reason="architecture has no decode step")

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.perf_counter()
    lowered, compiled = _lower_lm(cfg, shape, mesh)
    rep = _report(lowered, compiled, time.perf_counter() - t0)
    try:
        rep["extrapolated"] = _extrapolated_costs(cfg, shape, mesh)
    except (ValueError, NotImplementedError, RuntimeError) as e:
        # expected extrapolation failures: unsupported mesh arithmetic
        # (ValueError), collectives the model has no scaling law for
        # (NotImplementedError), XLA cost-analysis refusals (XlaRuntimeError
        # subclasses RuntimeError).  Anything else is a bug and propagates.
        rep["extrapolated"] = dict(error=f"{type(e).__name__}: {e}")
    # analytic model flops
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    rep.update(
        param_count=int(n_params), active_param_count=int(n_active),
        tokens_per_step=int(tokens),
        model_flops=float(factor * n_active * tokens),
    )
    return rep


# ---------------------------------------------------------------------------
# GENIE search cells (the paper's own workload at pod scale)
# ---------------------------------------------------------------------------

def run_genie_cell(dataset: str, mesh_kind: str) -> dict:
    from repro.configs.genie_datasets import DATASETS
    from repro.core import plan as plan_lib
    from repro.core.types import SearchParams

    ds = DATASETS[dataset]
    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(mesh.devices.shape))
    n = ((ds.n_objects + n_dev - 1) // n_dev) * n_dev
    q = ds.queries_per_batch
    # use_kernel=False: the dry-run lowers (and costs) the XLA fallback
    # engine; the Pallas path is costed analytically below.
    params = SearchParams(k=ds.default_k, use_kernel=False,
                          max_count=ds.m if ds.engine == "eq" else ds.dim)

    # Input shapes/dtypes are dataset metadata; the match function itself is
    # resolved from the MatchModel registry by engine name inside
    # make_search_step -- no per-engine dispatch here.
    if ds.engine == "eq":
        # signature dtype: narrowest int that holds the rehash domain
        # (hillclimb C: int8 SIFT signatures quarter the dominant HBM stream)
        sig_dt = jnp.int8 if ds.n_buckets <= 127 else (
            jnp.int16 if ds.n_buckets <= 32767 else jnp.int32)
        data_sds = jax.ShapeDtypeStruct((n, ds.m), sig_dt)
        query_sds = jax.ShapeDtypeStruct((q, ds.m), sig_dt)
    elif ds.engine == "minsum":
        data_sds = jax.ShapeDtypeStruct((n, ds.m), jnp.int8)
        query_sds = jax.ShapeDtypeStruct((q, ds.m), jnp.int8)
        params = SearchParams(k=ds.default_k, max_count=127, use_kernel=False)
    elif ds.engine == "ip":
        data_sds = jax.ShapeDtypeStruct((n, ds.m), jnp.int8)
        query_sds = jax.ShapeDtypeStruct((q, ds.m), jnp.int8)
        params = SearchParams(k=ds.default_k, max_count=ds.dim * 4, use_kernel=False)
    else:  # range: queries are the canonical (lo, hi) pytree
        data_sds = jax.ShapeDtypeStruct((n, ds.dim), jnp.int32)
        query_sds = (
            jax.ShapeDtypeStruct((q, ds.dim), jnp.int32),
            jax.ShapeDtypeStruct((q, ds.dim), jnp.int32),
        )
        params = SearchParams(k=ds.default_k, max_count=ds.dim, use_kernel=False)

    t0 = time.perf_counter()
    with mesh_lib.use_mesh(mesh):
        # segmented shard layout: data is segments concatenated in global-id
        # order and padded up to mesh divisibility (SegmentedIndex.concat_data);
        # n_objects masks the ragged pad tail out of every shard's buffer.
        # The plan is built once and both costed (describe) and lowered
        # (executable) -- the dry-run prices exactly the program that serves.
        plan = plan_lib.plan_search(
            ds.engine, params.k, params.max_count,
            layout=plan_lib.Layout.DISTRIBUTED, n_objects=ds.n_objects,
            use_kernel=params.use_kernel,
            hierarchical=(mesh_kind == "multi"
                          and tuple(mesh.axis_names)[0] == "pod"),
            mesh_axes=tuple(mesh.axis_names),
        )
        step = plan_lib.executable(plan, mesh=mesh)
        lowered = step.lower(data_sds, query_sds)
        compiled = lowered.compile()
        # routed serving variant (core/routing.py): same sharded layout plus
        # the replicated shard_active mask operand that blanks unrouted
        # shards' candidate buffers.  Lowered + compiled alongside the full
        # scan so the dry-run prices both programs the service can dispatch
        # (ROUTED_VERIFIED's fallback re-runs this same executable with an
        # all-ones mask, so these two cells are the entire serving surface).
        routed_plan = plan_lib.plan_search(
            ds.engine, params.k, params.max_count,
            layout=plan_lib.Layout.DISTRIBUTED, n_objects=ds.n_objects,
            use_kernel=params.use_kernel,
            hierarchical=(mesh_kind == "multi"
                          and tuple(mesh.axis_names)[0] == "pod"),
            mesh_axes=tuple(mesh.axis_names),
            routing="routed_verified",
        )
        t1 = time.perf_counter()
        routed_step = plan_lib.executable(routed_plan, mesh=mesh)
        routed_lowered = routed_step.lower(
            data_sds, query_sds, jax.ShapeDtypeStruct((n_dev,), jnp.int32))
        routed_compiled = routed_lowered.compile()
        routed_seconds = time.perf_counter() - t1
    rep = _report(lowered, compiled, time.perf_counter() - t0)
    rep["plan"] = plan.describe()
    rep["routing"] = _report(routed_lowered, routed_compiled, routed_seconds)
    rep["routing"]["plan"] = routed_plan.describe()
    # tuned-plan pricing (core/autotune.py): when this machine's measured
    # knob cache holds an entry for the dataset's shape, lower + compile the
    # tuned variant of the same cell next to the default, so the dry-run
    # prices exactly what a tuned service would dispatch.  No entry (the
    # common CI case) -> fingerprint recorded, nothing extra compiled.
    from repro.core import autotune as autotune_lib

    rep["autotune"] = dict(fingerprint=autotune_lib.hardware_fingerprint(),
                           entry=None)
    tune_cache = autotune_lib.resolve_cache(True)
    entry = (tune_cache.lookup(ds.engine, "wide", n=ds.n_objects)
             if tune_cache is not None else None)
    if entry is not None:
        rep["autotune"]["entry"] = entry.to_dict()
        t2 = time.perf_counter()
        with mesh_lib.use_mesh(mesh):
            tuned_plan = plan_lib.plan_search(
                ds.engine, params.k, params.max_count,
                layout=plan_lib.Layout.DISTRIBUTED, n_objects=ds.n_objects,
                use_kernel=params.use_kernel,
                hierarchical=(mesh_kind == "multi"
                              and tuple(mesh.axis_names)[0] == "pod"),
                mesh_axes=tuple(mesh.axis_names),
                autotune=tune_cache,
                tune_width=ds.m if ds.engine != "range" else ds.dim,
            )
            tuned_step = plan_lib.executable(tuned_plan, mesh=mesh)
            tuned_compiled = tuned_step.lower(data_sds, query_sds).compile()
        tuned_rep = _report(None, tuned_compiled, time.perf_counter() - t2)
        tuned_rep["plan"] = tuned_plan.describe()
        rep["autotune"]["tuned"] = tuned_rep
    # Pallas kernel cost model (per device): the deployable TPU path streams
    # the signature matrix once per query batch with VMEM-resident count
    # tiles; the XLA fallback engine recorded above re-reads its [Q, N]
    # accumulator every m/chunk scan step.  Both are reported; roofline uses
    # the kernel model for GENIE rows (EXPERIMENTS.md section Roofline).
    n_local = n // n_dev
    width = ds.m if ds.engine != "range" else ds.dim
    if ds.engine in ("minsum", "ip"):
        sig_bytes = 1
    elif ds.engine == "eq":
        sig_bytes = 1 if ds.n_buckets <= 127 else (2 if ds.n_buckets <= 32767 else 4)
    else:
        sig_bytes = 4
    kernel_flops = float(q) * n_local * width + float(q) * n_local  # match + hist
    if ds.engine == "ip":
        kernel_flops = 2.0 * q * n_local * width
    kernel_bytes = (
        n_local * width * sig_bytes        # signature/count matrix, read once
        + q * width * sig_bytes            # queries
        + 2.0 * q * n_local                # int8 counts write + hist read
    )
    rep.update(
        n_objects=int(n), n_queries=int(q), engine=ds.engine,
        # match cost: Q*N signature compares (the paper's "match" stage)
        model_flops=float(q) * n * (ds.m if ds.engine != "range" else ds.dim),
        kernel_model=dict(flops=kernel_flops, bytes_accessed=kernel_bytes),
    )
    # per-segment accounting for the streaming-ingest plan (core/segments.py):
    # the corpus arrives in 16 add()-sized batches, compacted 2:1 at serve
    # time; pad_rows is the ragged tail masked by the n_objects layout above.
    from repro.core import segments as seg_lib

    ingest_rows = seg_lib.even_segments(ds.n_objects, 16)
    compacted_rows = [sum(ingest_rows[i:i + 2]) for i in range(0, len(ingest_rows), 2)]
    rep["segmented"] = dict(
        pad_rows=int(n - ds.n_objects),
        ingest=seg_lib.layout_accounting(ingest_rows, width * sig_bytes),
        compacted=seg_lib.layout_accounting(compacted_rows, width * sig_bytes),
    )
    # signature-storage accounting (core/packing.py): wide vs PACKED bytes
    # per object, and the per-segment layouts a PACKED seal would produce.
    # The paper's five datasets serve WIDE-only engines (eq/minsum/ip/range
    # have no packed format), so packed reports None here; simhash/minhash
    # services (COSINE/TANIMOTO) shrink by the ratio gated in
    # benchmarks/roofline.py.
    from repro.core import engines as engines_lib

    model = engines_lib.get(ds.engine)
    packed_row_bytes = None
    if model.supports_packed:
        row_sds = jax.ShapeDtypeStruct((1, width), jnp.int32)
        packed_row_bytes = int(model.packed_bytes(row_sds))
    rep["segmented"]["signatures"] = dict(
        packed_supported=model.supports_packed,
        bytes_per_object_wide=int(width * sig_bytes),
        bytes_per_object_packed=packed_row_bytes,
        ingest_packed=(seg_lib.layout_accounting(ingest_rows, packed_row_bytes)
                       if packed_row_bytes else None),
        compacted_packed=(seg_lib.layout_accounting(compacted_rows, packed_row_bytes)
                          if packed_row_bytes else None),
    )
    return rep


# ---------------------------------------------------------------------------

def cell_path(kind: str, name: str, shape: str, mesh_kind: str) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    return os.path.join(REPORT_DIR, f"{kind}__{name}__{shape}__{mesh_kind}.json")


def run_and_save(kind: str, name: str, shape: str, mesh_kind: str, force: bool = False) -> dict:
    path = cell_path(kind, name, shape, mesh_kind)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    print(f"[dryrun] {kind} {name} {shape} {mesh_kind} ...", flush=True)
    try:
        rep = run_lm_cell(name, shape, mesh_kind) if kind == "lm" else run_genie_cell(name, mesh_kind)
    # Sweep boundary: a cell failure is a bug, but it must be recorded in
    # the grid (ok=False + traceback), not kill the remaining cells of an
    # hours-long compile sweep.
    # genielint: ignore[broad-except]
    except Exception as e:  # a failure here is a bug -- record it loudly
        rep = dict(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rep.update(kind=kind, name=name, shape=shape, mesh=mesh_kind)
    with open(path, "w") as f:
        json.dump(rep, f, indent=1)
    status = "OK" if rep.get("ok") else "FAIL"
    if rep.get("skipped"):
        status = "SKIP"
    print(f"[dryrun] {kind} {name} {shape} {mesh_kind}: {status} "
          f"({rep.get('compile_seconds', 0)}s)", flush=True)
    jax.clear_caches()
    return rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shapes_lib.SHAPES) + [None])
    ap.add_argument("--mesh", default=None, choices=["single", "multi", None])
    ap.add_argument("--genie", action="store_true", help="run GENIE search cells")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    failures = 0
    if args.genie or args.all:
        from repro.configs.genie_datasets import DATASETS
        for name in DATASETS:
            for mk in meshes:
                rep = run_and_save("genie", name, "search_1024q", mk, args.force)
                failures += 0 if rep.get("ok") else 1
    if not args.genie or args.all:
        archs = [args.arch] if args.arch else ALL_ARCHS
        shapes = [args.shape] if args.shape else list(shapes_lib.SHAPES)
        for arch in archs:
            for shape in shapes:
                for mk in meshes:
                    rep = run_and_save("lm", arch, shape, mk, args.force)
                    failures += 0 if rep.get("ok") else 1
    print(f"[dryrun] done, failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
