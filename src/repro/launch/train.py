"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 300 --global-batch 64 --seq 1024 --ckpt-dir /tmp/ck

On a real TPU pod each host runs this under the cluster scheduler
(jax.distributed.initialize picks up the pod topology); in this container it
runs on whatever devices exist.  The mesh is the production (data, model)
layout scaled down to the local device count; shardings come from
launch/sharding.py, identical code to the dry-run.
"""
import argparse

import jax

from repro.data.pipeline import DataConfig
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh_lib
from repro.models.registry import get_api, get_config, list_archs
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainHParams
from repro.train import step as tsl


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--remat", default="nothing", choices=["nothing", "dots", "none"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = get_api(cfg)
    mesh = mesh_lib.make_local_mesh(args.model_parallel)
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    hp = TrainHParams(
        optimizer=AdamWConfig(lr=args.lr), accum=args.accum,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        grad_compression=args.grad_compression, remat=args.remat,
    )
    tc = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, log_every=10)
    data = DataConfig(global_batch=args.global_batch, seq_len=args.seq)

    with mesh_lib.use_mesh(mesh):
        pshapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
        psh = sh_lib.params_shardings(pshapes, mesh, cfg.use_tp)
        ssh = sh_lib.state_shardings(
            jax.eval_shape(lambda: tsl.init_state(cfg, api, jax.random.PRNGKey(0), hp)),
            psh, mesh,
        )
        trainer = Trainer(cfg, api, hp, tc, data, shardings=ssh)
        history = trainer.run()
    for rec in history:
        print(f"step {rec['step']:6d}  loss {rec['loss']:.4f}  "
              f"lr {rec['lr']:.2e}  {rec['seconds']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
