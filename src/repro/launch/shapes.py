"""Assigned input shapes and per-(arch, shape) ShapeDtypeStruct builders.

    train_4k     seq=4096,   global_batch=256   (training)      -> train_step
    prefill_32k  seq=32768,  global_batch=32    (prefill)       -> prefill
    decode_32k   seq=32768,  global_batch=128   (decode)        -> decode_step
    long_500k    seq=524288, global_batch=1     (long decode)   -> decode_step,
                 sub-quadratic archs only (mamba2 / zamba2); pure full-attention
                 archs are recorded as skipped (DESIGN.md section 5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

SDS = jax.ShapeDtypeStruct


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "full quadratic attention at 500k context (assignment rule: skip)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Batch ShapeDtypeStructs for the full-sequence entry points
    (train_step / prefill): weak-type-correct, shardable, no allocation."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        return {
            "patch_embeds": SDS((b, cfg.n_patches, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((b, s - cfg.n_patches), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "frames": SDS((b, s, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((b, s), jnp.int32),
        }
    return {"tokens": SDS((b, s), jnp.int32)}


def token_specs(cfg: ModelConfig, shape: ShapeSpec) -> SDS:
    """Single decode-step token batch."""
    return SDS((shape.global_batch, 1), jnp.int32)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """KV/state cache ShapeDtypeStructs for decode cells (cap = seq_len)."""
    b, cap = shape.global_batch, shape.seq_len
    if cfg.family in ("dense", "moe"):
        return jax.eval_shape(lambda: transformer.init_cache(cfg, b, cap))
    if cfg.family == "vlm":
        return jax.eval_shape(lambda: transformer.init_cache(cfg, b, cap))
    if cfg.family == "ssm":
        return jax.eval_shape(lambda: ssm.init_cache(cfg, b))
    if cfg.family == "hybrid":
        return jax.eval_shape(lambda: hybrid.init_cache(cfg, b, cap))
    if cfg.family == "audio":
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        return {
            "k": SDS((cfg.n_layers, b, cap, kvh, hd), jnp.bfloat16),
            "v": SDS((cfg.n_layers, b, cap, kvh, hd), jnp.bfloat16),
            "memory": SDS((b, cap, cfg.d_model), jnp.bfloat16),
        }
    raise ValueError(cfg.family)
