# Launch layer: mesh construction, sharding policy, input shapes, dry-run,
# and the train/serve CLI drivers.  NOTE: dryrun must be executed as
# `python -m repro.launch.dryrun` (it sets XLA_FLAGS before importing jax);
# do not import it from code that already initialised jax.
from repro.launch import mesh, shapes, sharding  # noqa: F401
