"""Serving launcher: batched GENIE similarity search + LM decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m-smoke \
        --n-docs 20000 --n-queries 1024 --k 10
"""
import argparse
import time

import jax
import numpy as np

from repro.core.sa import document
from repro.data.pipeline import synthetic_documents
from repro.models.registry import get_api, get_config, list_archs
from repro.serve import RetrievalService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke", choices=list_archs())
    ap.add_argument("--n-docs", type=int, default=20_000)
    ap.add_argument("--n-queries", type=int, default=1024)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batches", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    table = np.asarray(params["embed"], np.float32)

    def embed(texts):
        vecs = document.binary_vectors(list(texts), min(cfg.vocab, 512)).astype(np.float32)
        return vecs @ table[: vecs.shape[1]]

    docs = synthetic_documents(args.n_docs, seed=0)
    svc = RetrievalService(embed_fn=embed, m_override=128, n_buckets=1024)
    t0 = time.perf_counter()
    svc.add(docs)
    print(f"indexed {args.n_docs} docs in {time.perf_counter()-t0:.2f}s")

    total, hits = 0, 0
    t0 = time.perf_counter()
    for b in range(args.batches):
        ids = (np.arange(args.n_queries) * 7 + b) % args.n_docs
        res, _ = svc.search([docs[i] for i in ids], k=args.k)
        hits += int(np.sum(np.asarray(res.ids)[:, 0] == ids))
        total += args.n_queries
    dt = time.perf_counter() - t0
    print(f"{total} queries in {dt:.2f}s -> {total/dt:.0f} qps; "
          f"top-1 self-retrieval {hits/total:.3f}")


if __name__ == "__main__":
    main()
