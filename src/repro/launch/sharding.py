"""Sharding policy: map every parameter / batch / cache tensor onto the
(pod, data, model) mesh with per-dimension divisibility checks.

Strategy (DESIGN.md section 4):
  * TP over "model": the largest divisible non-stack dimension of each weight
    (d_ff, head, or vocab dim in practice -- Megatron-style), biases/norms
    replicated.
  * FSDP (ZeRO-3) over "data": the largest remaining divisible dimension of
    each weight; optimizer moments inherit the same spec.
  * DP over ("pod", "data") for batch dims; parameters are replicated across
    "pod" (grad all-reduce crosses pods once per step).
  * Layer-stack leading dims (consumed by lax.scan) stay unsharded.
  * KV caches: batch over DP when divisible; kv-heads over "model" when
    divisible, else head_dim (always 16-divisible for the assigned archs).

Indivisible dims (smollm's 15 heads, mistral's kv=8, qwen2-moe's 60 experts)
simply fall through to the next candidate dimension -- the policy degrades
per-tensor instead of failing per-model.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.models.config import ModelConfig

# pytree path prefixes whose leading dim(s) are scan stacks
_STACK1 = ("blocks", "enc_blocks", "dec_blocks", "lora")
_STACK2 = ("mamba",)  # hybrid: [n_inv, period, ...]


def _path_keys(path) -> list[str]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
        else:
            keys.append(str(getattr(p, "idx", p)))
    return keys


def _n_stack_dims(keys: list[str]) -> int:
    for k in keys:
        if k in _STACK2:
            return 2
        if k in _STACK1:
            return 1
    return 0


# Megatron-style TP placement by weight name: which (negative, post-stack)
# dim is sharded over "model".  Column-parallel weights shard their OUTPUT
# dim (no extra comm); row-parallel weights shard their INPUT dim (one
# activation all-reduce after the matmul).  Sharding a contraction dim of a
# column-parallel weight instead inserts an all-reduce per projection --
# measured 67 GB/device/step of spurious all-reduce on smollm train_4k before
# this table existed (EXPERIMENTS.md section Perf, iteration 4).
_TP_RULES: dict[str, int | None] = {
    # attention: qkv column-parallel (heads out), wo row-parallel (heads in)
    "wq": -1, "wk": -1, "wv": -1, "wo": -2,
    # MLP: gate/up column-parallel (d_ff out), down row-parallel (d_ff in)
    "w_gate": -1, "w_up": -1, "w_down": -2,
    # embeddings: vocab-parallel table; head column-parallel (vocab out)
    "embed": -2, "lm_head": -1,
    # mamba2: fused in_proj stays model-replicated (its packed z|xBC|dt split
    # does not align with shard boundaries); SSD runs head-sharded via
    # activation hints; out_proj row-parallel
    "in_proj": None, "out_proj": -2, "conv_w": -1,
    # MoE: per-expert d_ff sharded (EP folds into TP only when E % 16 == 0)
    "router": None,
    # zamba2 LoRA: B column-parallel, A replicated over model
    "a_q": None, "b_q": -1,
    "shared_gate": None,
}


def param_spec(name: str, shape: tuple[int, ...], n_stack: int, tp: int, dp: int,
               use_tp: bool = True) -> P:
    axes: list[Any] = [None] * len(shape)
    free = list(range(n_stack, len(shape)))
    if len(free) >= 2:
        tp_dim = None
        rule = _TP_RULES.get(name, -1)  # default: column-parallel last dim
        if use_tp and rule is not None:
            cand = len(shape) + rule if rule < 0 else n_stack + rule
            if cand in free and shape[cand] % tp == 0:
                tp_dim = cand
                axes[tp_dim] = "model"
        rest = sorted((i for i in free if i != tp_dim), key=lambda i: -shape[i])
        # FSDP: without TP the "model" axis joins the ZeRO shard group.
        fsdp_groups = (("data",),) if use_tp else (("data", "model"), ("data",), ("model",))
        done = False
        for grp in fsdp_groups:
            size = dp if grp == ("data",) else (
                dp * tp if len(grp) == 2 else tp
            )
            for i in rest:
                if shape[i] % size == 0:
                    axes[i] = grp if len(grp) > 1 else grp[0]
                    done = True
                    break
            if done:
                break
    # 1-D (biases / norms / A_log): replicate
    return P(*axes)


def params_shardings(params_shapes: Any, mesh: jax.sharding.Mesh, use_tp: bool = True) -> Any:
    tp = mesh_lib.tp_size(mesh)
    dp = int(mesh.shape["data"])

    def one(path, leaf):
        keys = _path_keys(path)
        name = keys[-1] if keys else ""
        spec = param_spec(name, tuple(leaf.shape), _n_stack_dims(keys), tp, dp, use_tp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def state_shardings(state_shapes: Any, params_sh: Any, mesh: jax.sharding.Mesh) -> Any:
    """TrainState shardings: params/m/v share specs; scalars replicated."""
    rep = NamedSharding(mesh, P())

    def build(path, leaf):
        keys = _path_keys(path)
        if keys and keys[0] in ("params",):
            return _lookup(params_sh, keys[1:])
        if keys[:2] == ["opt", "m"] or keys[:2] == ["opt", "v"]:
            return _lookup(params_sh, keys[2:])
        if keys and keys[0] == "compress_error":
            return _lookup(params_sh, keys[1:]) if len(keys) > 1 else rep
        return rep

    return jax.tree_util.tree_map_with_path(build, state_shapes)


def _lookup(tree, keys):
    node = tree
    for k in keys:
        if isinstance(node, dict):
            node = node[k]
        elif isinstance(node, (list, tuple)):
            node = node[int(k)]
        else:
            node = getattr(node, k)
    return node


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_shardings(batch_shapes: dict, mesh: jax.sharding.Mesh, use_tp: bool = True) -> dict:
    dpa = mesh_lib.dp_axes(mesh)
    if not use_tp:
        dpa = dpa + ("model",)

    # candidate axis groups, largest first; contiguous subsets (not only
    # prefixes): global_batch=256 on the 512-chip mesh divides (data, model)
    # but not (pod, data, model) -- prefix-only search left the model axis
    # idle and 16x replicated activations (EXPERIMENTS.md Perf iter 8).
    import math as _m

    cands = [dpa[i:j] for i in range(len(dpa)) for j in range(len(dpa), i, -1)]
    cands.sort(key=lambda c: -_m.prod(mesh.shape[a] for a in c))

    def one(leaf):
        shape = tuple(leaf.shape)
        axes: list[Any] = [None] * len(shape)
        for cand in cands:
            total = _m.prod(mesh.shape[a] for a in cand)
            if shape and shape[0] % total == 0:
                axes[0] = cand if len(cand) > 1 else cand[0]
                break
            if len(shape) > 1 and shape[1] % total == 0:
                axes[1] = cand if len(cand) > 1 else cand[0]  # SP fallback
                break
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map(one, batch_shapes)


def _shard_batch_dim(axes, dim, size, mesh, dpa):
    """Greedy: shard `dim` over the longest divisible prefix of dpa.
    Returns the axes actually used (so other dims avoid them)."""
    cand = tuple(dpa)
    while cand:
        import math as _m

        total = _m.prod(mesh.shape[a] for a in cand)
        if size % total == 0:
            axes[dim] = cand if len(cand) > 1 else cand[0]
            return set(cand)
        cand = cand[:-1]
    return set()


def cache_shardings(cfg: ModelConfig, cache_shapes: Any, mesh: jax.sharding.Mesh) -> Any:
    """Decode caches dominate serving memory (a replicated mistral-large
    32k cache is ~1.5 TB); shard greedily: batch over the longest divisible
    DP prefix, kv-heads/head_dim over "model" when free, and finally the
    cache length itself over whatever axis remains (GSPMD handles the
    cross-shard attention reduction)."""
    dpa = mesh_lib.dp_axes(mesh)
    if not cfg.use_tp_serve:   # caches exist only on the serve path
        dpa = dpa + ("model",)
    tp = mesh_lib.tp_size(mesh)

    def kv_spec(shape):
        # [L/I, B, cap, KV, hd]
        axes: list[Any] = [None] * len(shape)
        used = _shard_batch_dim(axes, 1, shape[1], mesh, dpa)
        b, cap, kvh, hd = shape[1], shape[2], shape[3], shape[4]
        if "model" not in used:
            if kvh % tp == 0:
                axes[3] = "model"
            elif hd % tp == 0:
                axes[4] = "model"
            elif cap % tp == 0:
                axes[2] = "model"
        elif "data" not in used and cap % int(mesh.shape["data"]) == 0:
            axes[2] = "data"
        return P(*axes)

    def one(path, leaf):
        keys = _path_keys(path)
        shape = tuple(leaf.shape)
        name = keys[-1] if keys else ""
        if name in ("k", "v", "attn_k", "attn_v"):
            return NamedSharding(mesh, kv_spec(shape))
        axes: list[Any] = [None] * len(shape)
        if name == "memory":                       # [B, S, D]
            used = _shard_batch_dim(axes, 0, shape[0], mesh, dpa)
            if "model" not in used and shape[-1] % tp == 0:
                axes[-1] = "model"
            return NamedSharding(mesh, P(*axes))
        if name == "ssm":                          # [L(,P), B, h, n, p]
            bdim = len(shape) - 4
            used = _shard_batch_dim(axes, bdim, shape[bdim], mesh, dpa)
            if "model" not in used and shape[bdim + 1] % tp == 0:
                axes[bdim + 1] = "model"
            return NamedSharding(mesh, P(*axes))
        if name == "conv":                         # [L(,P), B, W-1, cch]
            bdim = len(shape) - 3
            used = _shard_batch_dim(axes, bdim, shape[bdim], mesh, dpa)
            if "model" not in used and shape[-1] % tp == 0:
                axes[-1] = "model"
            return NamedSharding(mesh, P(*axes))
        # fallback: replicate
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh: jax.sharding.Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
