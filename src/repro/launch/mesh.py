"""Production mesh construction (assignment-fixed shapes).

single pod : (data=16, model=16)            -- 256 chips (TPU v5e pod)
multi pod  : (pod=2, data=16, model=16)     -- 512 chips

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run forces 512 host devices via XLA_FLAGS before
any jax import; everything else sees the real device count).
"""
from __future__ import annotations

import contextlib

import jax


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Version-portable jax.make_mesh: `axis_types` only exists on newer jax
    (and Auto is already the default there); older releases reject the kwarg."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    """Version-portable ambient mesh: jax.sharding.set_mesh on newer jax,
    the Mesh context manager on older releases."""
    if hasattr(jax.sharding, "set_mesh"):
        with jax.sharding.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / CPU driver runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    names = tuple(mesh.axis_names)
    return ("pod", "data") if "pod" in names else ("data",)


def dp_size(mesh: jax.sharding.Mesh) -> int:
    return int(
        __import__("math").prod(mesh.shape[a] for a in dp_axes(mesh))
    )


def tp_size(mesh: jax.sharding.Mesh) -> int:
    return int(mesh.shape["model"])
