from repro.train import step, trainer  # noqa: F401
from repro.train.step import TrainHParams, TrainState, init_state, make_train_step  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
