"""Fault-tolerant training loop: checkpoint/resume, straggler tracking,
bounded-restart recovery.  The inner step is the jitted train_step from
train/step.py; everything here is host-side control."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from repro.runtime.fault_tolerance import RestartPolicy, StragglerDetector
from repro.train import step as train_step_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    async_checkpoint: bool = True
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        api: ModelApi,
        hp: train_step_lib.TrainHParams,
        tc: TrainerConfig,
        data: DataConfig,
        *,
        shardings=None,
        fail_injector: Optional[Callable[[int], None]] = None,
    ):
        self.cfg, self.api, self.hp, self.tc, self.data = cfg, api, hp, tc, data
        self.pipeline = SyntheticTokens(cfg, data)
        self.step_fn = jax.jit(train_step_lib.make_train_step(cfg, api, hp), donate_argnums=(0,))
        self.straggler = StragglerDetector(n_hosts=data.n_hosts)
        self.restart = RestartPolicy()
        self.recoveries = 0          # total failures survived (never forgiven)
        self._success_streak = 0
        self.fail_injector = fail_injector
        self._ckpt_thread = None
        self.shardings = shardings
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def _fresh_state(self):
        return train_step_lib.init_state(
            self.cfg, self.api, jax.random.PRNGKey(self.tc.seed), self.hp
        )

    def _try_resume(self, state):
        if not self.tc.ckpt_dir:
            return state, 0
        last = checkpointer.latest_step(self.tc.ckpt_dir)
        if last is None:
            return state, 0
        state, manifest = checkpointer.restore(self.tc.ckpt_dir, last, state, self.shardings)
        return state, int(manifest["extra"]["data_step"])

    def _checkpoint(self, state, data_step: int):
        if not self.tc.ckpt_dir:
            return
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        self._ckpt_thread = checkpointer.save(
            self.tc.ckpt_dir, data_step, state,
            extra=dict(data_step=data_step, arch=self.cfg.arch_id),
            async_=self.tc.async_checkpoint,
        )
        checkpointer.prune(self.tc.ckpt_dir, keep=self.tc.ckpt_keep)

    # ------------------------------------------------------------------
    def run(self) -> list[dict]:
        """Train to total_steps, recovering from injected/real step failures
        via restore-from-checkpoint with bounded backoff."""
        state = self._fresh_state()
        state, step = self._try_resume(state)
        while step < self.tc.total_steps:
            try:
                # perf_counter, not time(): straggler detection compares
                # per-step durations across hosts, and a wall-clock (NTP)
                # step would record a negative or inflated step time
                t0 = time.perf_counter()
                if self.fail_injector is not None:
                    self.fail_injector(step)
                batch = self.pipeline.batch(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                self.straggler.record(self.data.host_id, dt)
                step += 1
                if step % self.tc.log_every == 0 or step == self.tc.total_steps:
                    rec = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    rec.update(step=step, seconds=dt)
                    self.history.append(rec)
                if self.tc.ckpt_dir and step % self.tc.ckpt_every == 0:
                    self._checkpoint(state, step)
                self._success_streak += 1
                if self._success_streak >= 100:  # forgive old failures slowly
                    self.restart.on_success_window()
                    self._success_streak = 0
            except (RuntimeError, FloatingPointError) as e:  # step failure
                if "restart budget" in str(e):
                    raise
                self.recoveries += 1
                self._success_streak = 0
                delay = self.restart.on_failure()
                time.sleep(min(delay, 0.01))  # bounded in tests
                state = self._fresh_state()
                state, step = self._try_resume(state)
        if self.tc.ckpt_dir:
            self._checkpoint(state, step)
            if self._ckpt_thread is not None:
                self._ckpt_thread.join()
        self.final_state = state
        return self.history
