"""Training step: loss, microbatch gradient accumulation, optional gradient
compression, AdamW -- one jittable function per architecture."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import IGNORE, ModelApi
from repro.optim import adamw, compress, schedule


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: adamw.AdamWState
    compress_error: Any        # None when compression is off


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    accum: int = 1                       # microbatch accumulation factor
    aux_loss_weight: float = 0.01        # MoE load-balance loss
    grad_compression: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    remat: bool = True


def init_state(cfg: ModelConfig, api: ModelApi, key, hp: TrainHParams) -> TrainState:
    params = api.init_params(cfg, key)
    err = compress.init_error(params) if hp.grad_compression else None
    return TrainState(params=params, opt=adamw.init(params, hp.optimizer), compress_error=err)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray):
    """Mean CE over non-IGNORE positions.  logits [B, S, V] fp32."""
    mask = (labels != IGNORE).astype(jnp.float32)
    safe = jnp.where(labels == IGNORE, 0, labels)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(ce) / denom, denom


def make_loss_fn(cfg: ModelConfig, api: ModelApi, hp: TrainHParams):
    def loss_fn(params, batch):
        logits, aux, labels = api.train_logits(cfg, params, batch, remat=hp.remat)
        ce, ntok = cross_entropy(logits, labels)
        return ce + hp.aux_loss_weight * aux, dict(loss=ce, aux=aux, tokens=ntok)
    return loss_fn


def make_train_step(cfg: ModelConfig, api: ModelApi, hp: TrainHParams):
    """Returns train_step(state, batch) -> (state, metrics).  Jit with
    donate_argnums=(0,) and the shardings from launch.sharding."""
    loss_fn = make_loss_fn(cfg, api, hp)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulate(params, batch):
        if hp.accum == 1:
            return single(params, batch)
        split = lambda x: x.reshape((hp.accum, x.shape[0] // hp.accum) + x.shape[1:])
        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            g_acc, m_acc = carry
            g, m = single(params, mb)
            g_acc = jax.tree_util.tree_map(lambda a, b: a + b, g_acc, g)
            m_acc = jax.tree_util.tree_map(lambda a, b: a + b, m_acc, m)
            return (g_acc, m_acc), None

        g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = dict(loss=jnp.float32(0), aux=jnp.float32(0), tokens=jnp.float32(0))
        (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
        scale = 1.0 / hp.accum
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        metrics = {k: v * (scale if k != "tokens" else 1.0) for k, v in metrics.items()}
        return grads, metrics

    def train_step(state: TrainState, batch):
        grads, metrics = accumulate(state.params, batch)
        err = state.compress_error
        if hp.grad_compression:
            grads, err = compress.apply(grads, err)
        lr = schedule.cosine_with_warmup(
            state.opt.step, peak_lr=hp.optimizer.lr,
            warmup_steps=hp.warmup_steps, total_steps=hp.total_steps,
        )
        new_params, new_opt, gnorm = adamw.update(grads, state.opt, state.params, hp.optimizer, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr, step=new_opt.step)
        return TrainState(params=new_params, opt=new_opt, compress_error=err), metrics

    return train_step
