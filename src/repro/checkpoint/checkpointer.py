"""Sharded checkpointing with atomic commit and elastic restore.

Layout:  <dir>/step_<N>/
             manifest.json        step, arch, mesh shape, leaf index, data cursor
             arrays.npz           flattened leaves (key = joined tree path)

Writes go to step_<N>.tmp and are os.rename'd -- a preempted save never
corrupts the latest checkpoint.  `restore` device_puts each leaf with the
shardings of the *target* mesh, so a checkpoint written on one mesh shape
restores onto another (elastic shrink/grow); `latest_step` + the data cursor
give exactly-once resume.  On a real multi-host pod each host would write
`arrays.<host>.npz` with its addressable shards -- single-controller here,
one file (noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _key_of(p) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _path_key(path) -> str:
    return "/".join(_key_of(p) for p in path)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_key(path): np.asarray(leaf) for path, leaf in flat}


def save(
    ckpt_dir: str, step: int, state: Any, *, extra: Optional[dict] = None,
    async_: bool = False,
) -> threading.Thread | None:
    """Write checkpoint atomically; optionally in a background thread."""
    arrays = _flatten(state)           # host copies happen synchronously (consistent cut)

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = dict(step=step, n_leaves=len(arrays), extra=extra or {})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: Any, shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of `template`, placing leaves with
    `shardings` (same pytree structure, or None for default placement).

    Resharding across mesh shapes happens here: leaves are full logical
    arrays on host; device_put with the new mesh's NamedShardings re-slices.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    leaves_t, tdef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = [l for _, l in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    out = []
    for i, (pth, leaf) in enumerate(leaves_t):
        key = _path_key(pth)
        arr = arrays[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        if sh_leaves is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh_leaves[i]))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out
    )
    return tree, manifest


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest `keep` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
