"""Jit'd public wrappers around the Pallas kernels.

Each wrapper pads inputs to tile multiples (with values that cannot produce
spurious matches), dispatches to the kernel (interpret mode off-TPU), and
slices the result back to logical shape.  These are the functions the GENIE
engines call; repro.kernels.ref holds the oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels import cosine_count as _cos
from repro.kernels import cpq_hist as _cpq_hist
from repro.kernels import ip_count as _ip
from repro.kernels import match_count as _mc
from repro.kernels import minsum_count as _ms
from repro.kernels import packed_cosine as _pcos
from repro.kernels import packed_tanimoto as _ptan
from repro.kernels import range_count as _rc
from repro.kernels import tanimoto_count as _tc

# Padding sentinels: data and query pads differ so padded rows/cols never match.
_PAD_DATA = -1
_PAD_QUERY = -2


def _tiles(q: int, n: int, tq_pref: int, tn_pref: int) -> tuple[int, int]:
    tq = common.pick_tile(q, tq_pref, 8, knob="tile_q")
    tn = common.pick_tile(n, tn_pref, 128, knob="tile_n")
    return tq, tn


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "interpret"))
def match_count(
    data_sigs: jnp.ndarray,
    query_sigs: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """EQ engine kernel: counts int32 [Q, N]."""
    qn, m = query_sigs.shape
    nn = data_sigs.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _mc.TILE_Q, tile_n or _mc.TILE_N)
    q = common.pad_to(query_sigs.astype(jnp.int32), tq, 0, _PAD_QUERY)
    d = common.pad_to(data_sigs.astype(jnp.int32), tn, 0, _PAD_DATA)
    out = _mc.match_count_pallas(
        d, q, tile_q=tq, tile_n=tn, interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "interpret"))
def range_count(
    data_vals: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """RANGE engine kernel: counts int32 [Q, N]."""
    qn, d = q_lo.shape
    nn = data_vals.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _rc.TILE_Q, tile_n or _rc.TILE_N)
    # Padded queries use an empty range (lo > hi); padded data never matters
    # because the output is sliced.
    lo = common.pad_to(q_lo.astype(jnp.int32), tq, 0, 1)
    hi = common.pad_to(q_hi.astype(jnp.int32), tq, 0, 0)
    x = common.pad_to(data_vals.astype(jnp.int32), tn, 0, _PAD_DATA)
    out = _rc.range_count_pallas(
        x, lo, hi, tile_q=tq, tile_n=tn, interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_v", "interpret"))
def minsum_count(
    data_cnt: jnp.ndarray,
    query_cnt: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    tile_v: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """MINSUM engine kernel: counts int32 [Q, N]."""
    qn, v = query_cnt.shape
    nn = data_cnt.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _ms.TILE_Q, tile_n or _ms.TILE_N)
    tv = common.pick_tile(v, tile_v or _ms.TILE_V, 128, knob="tile_v")
    q = common.pad_to(common.pad_to(query_cnt.astype(jnp.int32), tq, 0, 0), tv, 1, 0)
    d = common.pad_to(common.pad_to(data_cnt.astype(jnp.int32), tn, 0, 0), tv, 1, 0)
    out = _ms.minsum_count_pallas(
        d, q, tile_q=tq, tile_n=tn, tile_v=tv, interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_v", "interpret"))
def ip_count(
    data_bin: jnp.ndarray,
    query_bin: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    tile_v: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """IP engine kernel: exact int32 counts [Q, N] (per-tile int32
    accumulation; no f32 magnitude bound)."""
    qn, v = query_bin.shape
    nn = data_bin.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _ip.TILE_Q, tile_n or _ip.TILE_N)
    tv = common.pick_tile(v, tile_v or _ip.TILE_V, 128, knob="tile_v")
    q = common.pad_to(common.pad_to(query_bin.astype(jnp.float32), tq, 0, 0), tv, 1, 0)
    d = common.pad_to(common.pad_to(data_bin.astype(jnp.float32), tn, 0, 0), tv, 1, 0)
    out = _ip.ip_count_pallas(
        d, q, tile_q=tq, tile_n=tn, tile_v=tv, interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_m", "interpret"))
def tanimoto_count(
    data_sigs: jnp.ndarray,
    query_sigs: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    tile_m: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """TANIMOTO engine kernel: minhash collision counts int32 [Q, N]."""
    qn, m = query_sigs.shape
    nn = data_sigs.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _tc.TILE_Q, tile_n or _tc.TILE_N)
    tm = common.pick_tile(m, tile_m or _tc.TILE_M, 128, knob="tile_m")
    # Distinct sentinels on every padded axis: padded signature slots never
    # collide, padded rows/cols are sliced away.
    q = common.pad_to(common.pad_to(query_sigs.astype(jnp.int32), tq, 0, _PAD_QUERY),
                      tm, 1, _PAD_QUERY)
    d = common.pad_to(common.pad_to(data_sigs.astype(jnp.int32), tn, 0, _PAD_DATA),
                      tm, 1, _PAD_DATA)
    out = _tc.tanimoto_count_pallas(
        d, q, tile_q=tq, tile_n=tn, tile_m=tm, interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_v", "interpret"))
def cosine_count(
    data_sgn: jnp.ndarray,
    query_sgn: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    tile_v: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """COSINE engine kernel: sign-agreement counts int32 [Q, N].

    Inputs are +-1 sign vectors; zero V-padding is dot-neutral and the kernel
    shifts by the logical V.  The kernel accumulates int32 (exact at any V).
    """
    qn, v = query_sgn.shape
    nn = data_sgn.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _cos.TILE_Q, tile_n or _cos.TILE_N)
    tv = common.pick_tile(v, tile_v or _cos.TILE_V, 128, knob="tile_v")
    q = common.pad_to(common.pad_to(query_sgn.astype(jnp.float32), tq, 0, 0), tv, 1, 0)
    d = common.pad_to(common.pad_to(data_sgn.astype(jnp.float32), tn, 0, 0), tv, 1, 0)
    out = _cos.cosine_count_pallas(
        d, q, v_logical=v, tile_q=tq, tile_n=tn, tile_v=tv,
        interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


# uint8 pad sentinels for packed TANIMOTO (buckets are capped at 253 by
# core/packing.py, so 254/255 can never collide with a real signature slot).
_PAD_DATA_U8 = 255
_PAD_QUERY_U8 = 254


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "interpret"))
def packed_cosine_count(
    data_words: jnp.ndarray,
    query_words: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Packed COSINE kernel: XOR+popcount agreement counts int32 [Q, N].

    Inputs are int32 word matrices from core/packing.py (query tail bits 1,
    data tail bits 0).  Pad rows are all-zero words -- their counts are
    garbage but sliced away; word-axis alignment is not needed because the
    kernel chunks the packed width in VMEM.
    """
    qn, w = query_words.shape
    nn = data_words.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _pcos.TILE_Q, tile_n or _pcos.TILE_N)
    q = common.pad_to(query_words.astype(jnp.int32), tq, 0, 0)
    d = common.pad_to(data_words.astype(jnp.int32), tn, 0, 0)
    out = _pcos.packed_cosine_count_pallas(
        d, q, bits_total=32 * w, tile_q=tq, tile_n=tn,
        interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def packed_cosine_topk(
    data_words: jnp.ndarray,
    query_words: jnp.ndarray,
    *,
    k: int,
    tile_q: int | None = None,
    tile_n: int | None = None,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused packed COSINE match->count->local-top-k.

    Returns (ids, counts) int32 [Q, n_tiles * min(k, tile_n)] candidate
    buffers in per-tile (count desc, id asc) order; ids are global object
    ids, pads are id -1 / count -1.  Data pad rows are masked in-kernel by
    global id, so they can never enter a tile's candidate list.
    """
    qn, w = query_words.shape
    nn = data_words.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _pcos.TILE_Q, tile_n or _pcos.TILE_N)
    q = common.pad_to(query_words.astype(jnp.int32), tq, 0, 0)
    d = common.pad_to(data_words.astype(jnp.int32), tn, 0, 0)
    ids, cnts = _pcos.packed_cosine_topk_pallas(
        d, q, bits_total=32 * w, n_logical=nn, k=k, tile_q=tq, tile_n=tn,
        interpret=common.use_interpret(interpret)
    )
    return ids[:qn], cnts[:qn]


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "tile_m", "interpret"))
def packed_tanimoto_count(
    data_u8: jnp.ndarray,
    query_u8: jnp.ndarray,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    tile_m: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Packed TANIMOTO kernel: byte-lane collision counts int32 [Q, N]."""
    qn, m = query_u8.shape
    nn = data_u8.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _ptan.TILE_Q, tile_n or _ptan.TILE_N)
    tm = common.pick_tile(m, tile_m or _ptan.TILE_M, 128, knob="tile_m")
    q = common.pad_to(common.pad_to(query_u8.astype(jnp.uint8), tq, 0, _PAD_QUERY_U8),
                      tm, 1, _PAD_QUERY_U8)
    d = common.pad_to(common.pad_to(data_u8.astype(jnp.uint8), tn, 0, _PAD_DATA_U8),
                      tm, 1, _PAD_DATA_U8)
    out = _ptan.packed_tanimoto_count_pallas(
        d, q, tile_q=tq, tile_n=tn, tile_m=tm, interpret=common.use_interpret(interpret)
    )
    return out[:qn, :nn]


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def packed_tanimoto_topk(
    data_u8: jnp.ndarray,
    query_u8: jnp.ndarray,
    *,
    k: int,
    tile_q: int | None = None,
    tile_n: int | None = None,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused packed TANIMOTO match->count->local-top-k (see
    packed_cosine_topk for the candidate-buffer contract)."""
    qn, m = query_u8.shape
    nn = data_u8.shape[0]
    tq, tn = _tiles(qn, nn, tile_q or _ptan.TILE_Q, tile_n or _ptan.TILE_N)
    q = common.pad_to(query_u8.astype(jnp.uint8), tq, 0, _PAD_QUERY_U8)
    d = common.pad_to(data_u8.astype(jnp.uint8), tn, 0, _PAD_DATA_U8)
    ids, cnts = _ptan.packed_tanimoto_topk_pallas(
        d, q, n_logical=nn, k=k, tile_q=tq, tile_n=tn,
        interpret=common.use_interpret(interpret)
    )
    return ids[:qn], cnts[:qn]


@functools.partial(jax.jit, static_argnames=("max_count", "tile_q", "tile_n", "interpret"))
def cpq_hist(
    counts: jnp.ndarray,
    max_count: int,
    *,
    tile_q: int | None = None,
    tile_n: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """c-PQ Gate histogram: int32 [Q, max_count + 1]."""
    qn, nn = counts.shape
    tq = common.pick_tile(qn, tile_q or _cpq_hist.TILE_Q, 8, knob="tile_q")
    tn = common.pick_tile(nn, tile_n or _cpq_hist.TILE_N, 128, knob="tile_n")
    nbins = common.ceil_to(max_count + 1, 128)
    c = common.pad_to(common.pad_to(counts.astype(jnp.int32), tq, 0, -1), tn, 1, -1)
    out = _cpq_hist.cpq_hist_pallas(
        c, nbins, tile_q=tq, tile_n=tn, interpret=common.use_interpret(interpret)
    )
    return out[:qn, : max_count + 1]
