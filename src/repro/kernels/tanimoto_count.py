"""Pallas TPU kernel: TANIMOTO match-count (minhash sketch collisions).

counts[q, n] = sum_i (data_sigs[n, i] == query_sigs[q, i])

Minhash collision counting -- Pr[h(S) = h(T)] = J(S, T), so counts are
Binomial(m, J) draws and c/m is the Jaccard MLE (FLASH, Wang et al.,
1709.01190).  Unlike the EQ kernel (match_count.py), which holds the whole
signature width in VMEM per block, FLASH-scale sketches use thousands of hash
functions, so here the signature axis m is the third grid dimension: [TQ, TM]
and [TN, TM] signature slabs stream through VMEM and partial collision counts
accumulate into the output tile across the M grid steps (same streaming
pattern as the MINSUM vocabulary axis).

Grid: (Q/TILE_Q, N/TILE_N, M/TILE_M), output revisited along the last axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
TILE_M = 512
CHUNK = 8


def _tanimoto_kernel(q_ref, d_ref, o_ref, *, tile_m: int, chunk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]  # [TQ, TM] int32
    d = d_ref[...]  # [TN, TM]
    acc = jnp.zeros((q.shape[0], d.shape[0]), dtype=jnp.int32)
    for s in range(0, tile_m, chunk):  # static unroll, [TQ, TN, chunk] temps
        e = min(s + chunk, tile_m)
        hit = q[:, None, s:e] == d[None, :, s:e]
        acc = acc + jnp.sum(hit.astype(jnp.int32), axis=-1)
    o_ref[...] += acc


def tanimoto_count_pallas(
    data_sigs: jnp.ndarray,
    query_sigs: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    tile_m: int = TILE_M,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """counts int32 [Q, N].  Inputs pre-padded (ops.py): Q % tile_q == 0,
    N % tile_n == 0, m % tile_m == 0 with non-colliding sentinels in the pad."""
    qn, m = query_sigs.shape
    nn = data_sigs.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0 and m % tile_m == 0
    grid = (qn // tile_q, nn // tile_n, m // tile_m)
    kernel = functools.partial(_tanimoto_kernel, tile_m=tile_m, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_m), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_m), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_sigs.astype(jnp.int32), data_sigs.astype(jnp.int32))
