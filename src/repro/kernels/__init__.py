# Pallas TPU kernels for GENIE's compute hot-spots (match-count engines and
# the c-PQ gate histogram).  Each kernel module holds the pl.pallas_call +
# BlockSpec implementation; ops.py is the jit'd public wrapper; ref.py the
# pure-jnp oracle.  Off-TPU they run in interpret mode.
