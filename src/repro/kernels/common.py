"""Shared utilities for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling); on any other
backend (this CPU container) they run in interpret mode, executing the kernel
body in Python for bit-exact validation against the ref.py oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return not on_tpu()
    return interpret


def pad_to(x: jnp.ndarray, multiple: int, axis: int, value) -> jnp.ndarray:
    """Pad `axis` of x up to the next multiple with a constant."""
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return jnp.pad(x, widths, constant_values=value)


def ceil_to(size: int, multiple: int) -> int:
    return -(-size // multiple) * multiple


def pick_tile(size: int, preferred: int, align: int, knob: str = "tile") -> int:
    """Tile size: `preferred` when the dim is big enough, else the whole
    (alignment-padded) dim.

    `preferred` may come from a tuned plan (core/autotune.py), so a bad value
    fails loudly with the caller's knob name instead of emitting a degenerate
    grid: alignment must be positive and `preferred` must reach the alignment
    floor (the TPU min-tile lane/sublane width the kernels assume)."""
    align = int(align)
    preferred = int(preferred)
    if align <= 0:
        raise ValueError(
            f"{knob}: tile alignment must be > 0, got align={align}"
        )
    if preferred < align:
        raise ValueError(
            f"{knob}={preferred} is below the alignment floor {align}: a "
            f"sub-aligned tile would emit a degenerate grid; tuned tiles "
            f"must be multiples of the min-tile width (>= {align})"
        )
    if size >= preferred:
        return preferred
    return ceil_to(max(size, 1), align)
