"""Pure-jnp oracles for every Pallas kernel (the reference semantics).

The engine references live in repro.core.match; they are re-exported here so
tests can sweep (kernel vs ref) from one import site.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.match import (  # noqa: F401
    match_cosine,
    match_eq,
    match_ip,
    match_minsum,
    match_range,
    match_tanimoto,
    tanimoto_exact,
)


def cpq_hist(counts: jnp.ndarray, nbins: int) -> jnp.ndarray:
    """hist[q, t] = #{n : counts[q, n] == t} for t in [0, nbins)."""
    c = counts.astype(jnp.int32)
    bins = jnp.arange(nbins, dtype=jnp.int32)
    return jnp.sum((c[..., None] == bins).astype(jnp.int32), axis=1)
