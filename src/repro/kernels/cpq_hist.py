"""Pallas TPU kernel: c-PQ count histogram (the Gate's ZipperArray source).

hist[q, t] = #{ n : counts[q, n] == t },  t in [0, nbins)

The c-PQ Gate (paper section III-C) needs ZA[t] = #{count >= t}; since counts
live in the bounded domain [0, max_count] (the Bitmap-Counter observation),
ZA is the suffix-sum of this histogram.  The kernel streams count tiles from
HBM and accumulates per-query histograms in the output VMEM block across the
N grid axis; the AuditThreshold and candidate compaction are computed from the
histogram in core/cpq.py.  Padded count entries are -1 and match no bin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 8     # queries per cell (keeps the one-hot temp in VMEM)
TILE_N = 512   # counts per cell


def _cpq_hist_kernel(c_ref, h_ref, *, nbins: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    c = c_ref[...].astype(jnp.int32)                       # [TQ, TN]
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nbins), 2)
    onehot = (c[:, :, None] == bins).astype(jnp.int32)     # [TQ, TN, B]
    h_ref[...] += jnp.sum(onehot, axis=1)


def cpq_hist_pallas(
    counts: jnp.ndarray,
    nbins: int,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """hist int32 [Q, nbins]; counts int [Q, N] padded with -1, Q % tile_q == 0,
    N % tile_n == 0, nbins % 128 == 0 (ops.py pads; extra bins read zero)."""
    qn, nn = counts.shape
    assert qn % tile_q == 0 and nn % tile_n == 0
    grid = (qn // tile_q, nn // tile_n)
    kernel = functools.partial(_cpq_hist_kernel, nbins=nbins)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile_q, nbins), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qn, nbins), jnp.int32),
        interpret=interpret,
    )(counts)
