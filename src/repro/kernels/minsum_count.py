"""Pallas TPU kernel: MINSUM match-count (SA n-gram multiset intersection).

counts[q, n] = sum_v min(data_cnt[n, v], query_cnt[q, v])

Lemma 5.1's ordered-n-gram match count over per-gram-type multiplicity
vectors.  The gram-vocabulary axis V is tiled through the grid (third grid
dim) so arbitrarily large vocabularies stream through VMEM; partial sums
accumulate into the output tile across the V grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
TILE_V = 512
CHUNK = 8


def _minsum_kernel(q_ref, d_ref, o_ref, *, tile_v: int, chunk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...]  # [TQ, TV] int32
    d = d_ref[...]  # [TN, TV]
    acc = jnp.zeros((q.shape[0], d.shape[0]), dtype=jnp.int32)
    for s in range(0, tile_v, chunk):
        e = min(s + chunk, tile_v)
        acc = acc + jnp.sum(jnp.minimum(q[:, None, s:e], d[None, :, s:e]), axis=-1)
    o_ref[...] += acc


def minsum_count_pallas(
    data_cnt: jnp.ndarray,
    query_cnt: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    tile_v: int = TILE_V,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    qn, v = query_cnt.shape
    nn = data_cnt.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0 and v % tile_v == 0
    grid = (qn // tile_q, nn // tile_n, v // tile_v)
    kernel = functools.partial(_minsum_kernel, tile_v=tile_v, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_v), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_v), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_cnt.astype(jnp.int32), data_cnt.astype(jnp.int32))
