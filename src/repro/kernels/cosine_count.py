"""Pallas TPU kernel: COSINE match-count (sign-agreement via +-1 MXU matmul).

counts[q, n] = (V + sum_v query_sgn[q, v] * data_sgn[n, v]) / 2

Sign-quantized (simhash-style) cosine at billion scale (Johnson et al.,
1702.08734): the agreement count of sign bits equals the shifted +-1 inner
product, so the compare rides the MXU as a tiled matmul with bf16 +-1 inputs
(exact products).  Each V grid step's partial dot lies in [-tile_v, tile_v]
-- exact in f32 -- and is cast to int32 before accumulating into the output
tile, so the running sum and the final (V + dot) // 2 shift are pure integer
arithmetic: the kernel emits int32 counts with no f32 magnitude bound on V
(the old f32 accumulator capped exactness at 2^24).  Zero pad rows
(multiload fill) floor to V // 2 and are masked upstream by global id.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
TILE_V = 512


def _cosine_kernel(q_ref, d_ref, o_ref, *, v_logical: int, n_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # per-step dot <= tile_v in magnitude: exact in f32, lossless int32 cast
    step = jnp.dot(q_ref[...], d_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] += step.astype(jnp.int32)

    @pl.when(k == n_steps - 1)
    def _finalize():
        # agreements = (V + dot) // 2; exact -- V + dot is even whenever the
        # row is genuinely +-1, and integer floor-div matches the reference
        # for zero pad rows.
        o_ref[...] = (v_logical + o_ref[...]) // 2


def cosine_count_pallas(
    data_sgn: jnp.ndarray,
    query_sgn: jnp.ndarray,
    *,
    v_logical: int,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    tile_v: int = TILE_V,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns int32 [Q, N] agreement counts (one dtype contract with the
    packed XOR+popcount path in packed_cosine.py).

    Inputs are +-1 (bf16/f32/int) pre-padded by ops.py: zero-fill on the V
    axis is dot-neutral, so `v_logical` (the unpadded V) sets the shift.
    """
    qn, v = query_sgn.shape
    nn = data_sgn.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0 and v % tile_v == 0
    grid = (qn // tile_q, nn // tile_n, v // tile_v)
    kernel = functools.partial(
        _cosine_kernel, v_logical=v_logical, n_steps=v // tile_v
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_v), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_v), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_sgn.astype(jnp.bfloat16), data_sgn.astype(jnp.bfloat16))
