"""Pallas TPU kernels: packed TANIMOTO match-count (uint8 minhash buckets).

When the minhash rehash domain fits a byte (core/packing.py caps it at 253;
254/255 are the pad sentinels), bucket ids narrow from int32 to uint8 -- 4x
fewer bytes off HBM for the dominant data stream -- and the match stays the
same equality compare, now on byte lanes.  Counts are bit-for-bit identical
to the wide kernel (tanimoto_count.py).

Two entry points:
  packed_tanimoto_count_pallas -- counts int32 [Q, N]; the signature axis m
      streams through the grid exactly like the wide kernel (FLASH-scale m
      never resides whole in VMEM), just in quarter-width slabs.
  packed_tanimoto_topk_pallas  -- fused match -> count -> per-tile local
      top-k (grid (qi, nj), whole packed m per block): each tile extracts
      its kc best (count desc, id asc) candidates in VMEM and writes only
      [Q, n_tiles * kc] id/count buffers to HBM instead of [Q, N] counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packed_cosine import local_topk_tile

TILE_Q = 128
TILE_N = 256
TILE_M = 512
CHUNK = 8


def _byte_collision_counts(q, d, *, chunk: int) -> jnp.ndarray:
    """Collision counts [TQ, TN] from uint8 tiles [TQ, M] / [TN, M]."""
    m = q.shape[1]
    acc = jnp.zeros((q.shape[0], d.shape[0]), dtype=jnp.int32)
    for s in range(0, m, chunk):  # static unroll, [TQ, TN, chunk] temps
        e = min(s + chunk, m)
        hit = q[:, None, s:e] == d[None, :, s:e]
        acc = acc + jnp.sum(hit.astype(jnp.int32), axis=-1)
    return acc


def _count_kernel(q_ref, d_ref, o_ref, *, tile_m: int, chunk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += _byte_collision_counts(q_ref[...], d_ref[...], chunk=chunk)


def packed_tanimoto_count_pallas(
    data_u8: jnp.ndarray,
    query_u8: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    tile_m: int = TILE_M,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """counts int32 [Q, N].  Inputs pre-padded (ops.py): Q % tile_q == 0,
    N % tile_n == 0, m % tile_m == 0 with the 254/255 sentinels in the pad."""
    qn, m = query_u8.shape
    nn = data_u8.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0 and m % tile_m == 0
    grid = (qn // tile_q, nn // tile_n, m // tile_m)
    kernel = functools.partial(_count_kernel, tile_m=tile_m, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_m), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_m), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_u8.astype(jnp.uint8), data_u8.astype(jnp.uint8))


def _topk_kernel(q_ref, d_ref, ids_ref, cnt_ref, *,
                 chunk: int, tile_n: int, kc: int, n_logical: int):
    j = pl.program_id(1)
    counts = _byte_collision_counts(q_ref[...], d_ref[...], chunk=chunk)
    gid = j * tile_n + jax.lax.broadcasted_iota(jnp.int32, counts.shape, 1)
    counts = jnp.where(gid < n_logical, counts, jnp.int32(-1))
    ids, cnts = local_topk_tile(counts, gid, kc)
    ids_ref[...] = ids
    cnt_ref[...] = cnts


def packed_tanimoto_topk_pallas(
    data_u8: jnp.ndarray,
    query_u8: jnp.ndarray,
    *,
    n_logical: int,
    k: int,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused match -> count -> local top-k.  Returns (ids, counts), both
    int32 [Q, n_tiles * kc] with kc = min(k, tile_n): per-tile candidates in
    (count desc, id asc) order, pads as id -1 / count -1.  Holds the whole
    packed m per block (byte slabs are 4x smaller than the wide kernel's)."""
    qn, m = query_u8.shape
    nn = data_u8.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0
    kc = min(k, tile_n)
    n_tiles = nn // tile_n
    grid = (qn // tile_q, n_tiles)
    kernel = functools.partial(
        _topk_kernel, chunk=chunk, tile_n=tile_n, kc=kc, n_logical=n_logical
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, kc), lambda i, j: (i, j)),
            pl.BlockSpec((tile_q, kc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, n_tiles * kc), jnp.int32),
            jax.ShapeDtypeStruct((qn, n_tiles * kc), jnp.int32),
        ],
        interpret=interpret,
    )(query_u8.astype(jnp.uint8), data_u8.astype(jnp.uint8))
