"""Pallas TPU kernel: EQ match-count (LSH signature compare).

counts[q, n] = sum_i (data_sigs[n, i] == query_sigs[q, i])

This is GENIE's inverted-index scan re-expressed for the TPU (DESIGN.md
section 2): instead of scanning postings lists with atomic counter updates,
each grid cell compares a [TILE_Q, m] query-signature block against a
[TILE_N, m] data-signature block held in VMEM and emits a dense [TILE_Q,
TILE_N] count tile.  The compare runs on the VPU in m/CHUNK vectorised steps;
the signature matrix streams from HBM exactly once per query tile, giving the
memory-bound roofline analysed in EXPERIMENTS.md.

Grid: (Q/TILE_Q, N/TILE_N); each cell is independent (embarrassingly
parallel -- the TPU analogue of the paper's "one block per query item" with
perfect load balance by construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128   # query rows per grid cell
TILE_N = 256   # objects per grid cell (minor-most in the output tile)
CHUNK = 8      # hash functions folded per vector step ([TQ, TN, CHUNK] temp)


def _match_count_kernel(q_ref, d_ref, o_ref, *, m: int, chunk: int):
    q = q_ref[...]  # [TQ, Mp] int32
    d = d_ref[...]  # [TN, Mp] int32
    acc = jnp.zeros((q.shape[0], d.shape[0]), dtype=jnp.int32)
    for s in range(0, m, chunk):  # static unroll over signature chunks
        e = min(s + chunk, m)
        qs = q[:, s:e]
        ds = d[:, s:e]
        hit = qs[:, None, :] == ds[None, :, :]             # [TQ, TN, c]
        acc = acc + jnp.sum(hit.astype(jnp.int32), axis=-1)
    o_ref[...] = acc


def match_count_pallas(
    data_sigs: jnp.ndarray,
    query_sigs: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """counts int32 [Q, N].  Inputs must already be padded: Q % tile_q == 0,
    N % tile_n == 0 (ops.py handles padding/slicing)."""
    qn, m = query_sigs.shape
    nn = data_sigs.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0, (qn, nn, tile_q, tile_n)
    grid = (qn // tile_q, nn // tile_n)
    kernel = functools.partial(_match_count_kernel, m=m, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_sigs.astype(jnp.int32), data_sigs.astype(jnp.int32))
