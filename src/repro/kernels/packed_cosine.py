"""Pallas TPU kernels: packed COSINE match-count via XOR + popcount.

Signatures arrive bit-packed (core/packing.py): 32 signs per int32 word, so
a [N, V] int8 sign matrix streams as [N, ceil(V/32)] words -- 8x fewer bytes
off HBM.  The agreement count is recovered without unpacking:

    counts[q, n] = bits_total - popcount(q_words[q] XOR d_words[n])

where bits_total = 32 * W_logical and the packing guarantees query tail bits
(past V in the last word) are 1 while data tail bits are 0, so every tail
bit is a disagreement and the identity needs no knowledge of V.  Word-axis
pad (to the chunk multiple) is 0 on both sides: XOR 0 -> popcount 0,
combine-neutral.  Counts are bit-for-bit identical to the wide MXU kernel
(cosine_count.py) -- the FLASH trick (Wang et al., 1709.01190) on the VPU.

Two entry points:
  packed_cosine_count_pallas  -- counts int32 [Q, N] (grid (qi, nj), whole
      packed width per block; W is 32x smaller than V so it always fits).
  packed_cosine_topk_pallas   -- the fused match -> count -> per-tile local
      top-k: each (qi, nj) tile extracts its kc best (count desc, id asc)
      candidates in VMEM and writes only [Q, n_tiles * kc] id/count buffers
      to HBM instead of the full [Q, N] count matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
CHUNK = 8

# plain ints (not jnp scalars): module-level arrays would be captured as
# pallas kernel constants, which pallas_call rejects
_NEG_INF = -(2**31) + 1
_POS_INF = 2**31 - 1


def _xor_popcount_counts(q, d, *, bits_total: int, chunk: int) -> jnp.ndarray:
    """Agreement counts [TQ, TN] from packed word tiles [TQ, W] / [TN, W]."""
    w = q.shape[1]
    acc = jnp.zeros((q.shape[0], d.shape[0]), dtype=jnp.int32)
    for s in range(0, w, chunk):  # static unroll, [TQ, TN, chunk] temps
        e = min(s + chunk, w)
        x = jax.lax.population_count(q[:, None, s:e] ^ d[None, :, s:e])
        acc = acc + jnp.sum(x, axis=-1)
    return bits_total - acc


def local_topk_tile(counts: jnp.ndarray, gid: jnp.ndarray, kc: int):
    """Per-tile local top-k by iterative extraction, (count desc, id asc).

    counts int32 [TQ, TN] (pad columns pre-masked to -1), gid int32 [TQ, TN]
    global object ids.  Returns (ids [TQ, kc], counts [TQ, kc]); exhausted
    slots (only pads left) emit id -1 / count -1.  Equal-count candidates
    appear in ascending-id order, which topk_from_candidates' stable merge
    relies on for the global tie-break.
    """
    work = counts
    id_cols, cnt_cols = [], []
    for _ in range(kc):
        best = jnp.max(work, axis=1)                          # [TQ]
        at_best = work == best[:, None]
        bid = jnp.min(jnp.where(at_best, gid, jnp.int32(_POS_INF)), axis=1)
        id_cols.append(jnp.where(best < 0, jnp.int32(-1), bid))
        cnt_cols.append(jnp.maximum(best, jnp.int32(-1)))
        work = jnp.where(gid == bid[:, None], jnp.int32(_NEG_INF), work)
    return jnp.stack(id_cols, axis=1), jnp.stack(cnt_cols, axis=1)


def _count_kernel(q_ref, d_ref, o_ref, *, bits_total: int, chunk: int):
    o_ref[...] = _xor_popcount_counts(
        q_ref[...], d_ref[...], bits_total=bits_total, chunk=chunk
    )


def packed_cosine_count_pallas(
    data_words: jnp.ndarray,
    query_words: jnp.ndarray,
    *,
    bits_total: int,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    """counts int32 [Q, N].  Inputs pre-padded (ops.py): Q % tile_q == 0,
    N % tile_n == 0, word axis 0-padded; bits_total = 32 * W_logical."""
    qn, w = query_words.shape
    nn = data_words.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0
    grid = (qn // tile_q, nn // tile_n)
    kernel = functools.partial(_count_kernel, bits_total=bits_total, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_words.astype(jnp.int32), data_words.astype(jnp.int32))


def _topk_kernel(q_ref, d_ref, ids_ref, cnt_ref, *,
                 bits_total: int, chunk: int, tile_n: int, kc: int,
                 n_logical: int):
    j = pl.program_id(1)
    counts = _xor_popcount_counts(
        q_ref[...], d_ref[...], bits_total=bits_total, chunk=chunk
    )
    gid = j * tile_n + jax.lax.broadcasted_iota(jnp.int32, counts.shape, 1)
    counts = jnp.where(gid < n_logical, counts, jnp.int32(-1))
    ids, cnts = local_topk_tile(counts, gid, kc)
    ids_ref[...] = ids
    cnt_ref[...] = cnts


def packed_cosine_topk_pallas(
    data_words: jnp.ndarray,
    query_words: jnp.ndarray,
    *,
    bits_total: int,
    n_logical: int,
    k: int,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused match -> count -> local top-k.  Returns (ids, counts), both
    int32 [Q, n_tiles * kc] with kc = min(k, tile_n): per-tile candidates in
    (count desc, id asc) order, pads as id -1 / count -1.  Only these
    candidate buffers touch HBM -- the [Q, N] count matrix never leaves
    VMEM."""
    qn, w = query_words.shape
    nn = data_words.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0
    kc = min(k, tile_n)
    n_tiles = nn // tile_n
    grid = (qn // tile_q, n_tiles)
    kernel = functools.partial(
        _topk_kernel, bits_total=bits_total, chunk=chunk,
        tile_n=tile_n, kc=kc, n_logical=n_logical,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, kc), lambda i, j: (i, j)),
            pl.BlockSpec((tile_q, kc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, n_tiles * kc), jnp.int32),
            jax.ShapeDtypeStruct((qn, n_tiles * kc), jnp.int32),
        ],
        interpret=interpret,
    )(query_words.astype(jnp.int32), data_words.astype(jnp.int32))
