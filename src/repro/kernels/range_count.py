"""Pallas TPU kernel: RANGE match-count (relational range queries).

counts[q, n] = sum_d (q_lo[q, d] <= data_vals[n, d] <= q_hi[q, d])

The relational inverted index of paper Example 2.1 maps each (attribute,
value) pair to a postings list and a query item to a contiguous run of
lists; the equivalent dense computation is a per-attribute interval test.
Same grid/tiling scheme as match_count (VPU, two compares per attribute).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
CHUNK = 8


def _range_count_kernel(lo_ref, hi_ref, x_ref, o_ref, *, d: int, chunk: int):
    lo = lo_ref[...]  # [TQ, Dp]
    hi = hi_ref[...]
    x = x_ref[...]    # [TN, Dp]
    acc = jnp.zeros((lo.shape[0], x.shape[0]), dtype=jnp.int32)
    for s in range(0, d, chunk):
        e = min(s + chunk, d)
        xs = x[None, :, s:e]
        hit = (xs >= lo[:, None, s:e]) & (xs <= hi[:, None, s:e])
        acc = acc + jnp.sum(hit.astype(jnp.int32), axis=-1)
    o_ref[...] = acc


def range_count_pallas(
    data_vals: jnp.ndarray,
    q_lo: jnp.ndarray,
    q_hi: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    chunk: int = CHUNK,
    interpret: bool = False,
) -> jnp.ndarray:
    qn, d = q_lo.shape
    nn = data_vals.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0
    grid = (qn // tile_q, nn // tile_n)
    kernel = functools.partial(_range_count_kernel, d=d, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(q_lo.astype(jnp.int32), q_hi.astype(jnp.int32), data_vals.astype(jnp.int32))
