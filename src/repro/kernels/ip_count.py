"""Pallas TPU kernel: IP match-count (binary inner product on the MXU).

counts[q, n] = sum_v query_bin[q, v] * data_bin[n, v]

The short-document model (paper section V-B): MC == inner product of binary
word vectors.  Unlike the VPU compare kernels this one rides the MXU -- a
classic tiled matmul with bf16 {0,1} inputs, giving the compute-bound
roofline corner of the engine family.  Each V grid step's partial dot lies
in [0, tile_v] -- exact in f32 -- and is cast to int32 before accumulating
into the output tile, so the kernel emits exact int32 counts with no f32
magnitude bound on V (the registry's count-dtype policy; the old f32
accumulator + post-hoc round capped exactness at 2^24, the same drift the
cosine kernel shed in PR 6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
TILE_V = 512


def _ip_kernel(q_ref, d_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # per-step dot <= tile_v in magnitude: exact in f32, lossless int32 cast
    step = jnp.dot(q_ref[...], d_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] += step.astype(jnp.int32)


def ip_count_pallas(
    data_bin: jnp.ndarray,
    query_bin: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    tile_v: int = TILE_V,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns exact int32 [Q, N] counts.  Inputs bf16/f32/int {0,1}."""
    qn, v = query_bin.shape
    nn = data_bin.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0 and v % tile_v == 0
    grid = (qn // tile_q, nn // tile_n, v // tile_v)
    return pl.pallas_call(
        _ip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_v), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_v), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.int32),
        interpret=interpret,
    )(query_bin.astype(jnp.bfloat16), data_bin.astype(jnp.bfloat16))
