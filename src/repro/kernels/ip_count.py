"""Pallas TPU kernel: IP match-count (binary inner product on the MXU).

counts[q, n] = sum_v query_bin[q, v] * data_bin[n, v]

The short-document model (paper section V-B): MC == inner product of binary
word vectors.  Unlike the VPU compare kernels this one rides the MXU -- a
classic tiled matmul with bf16 inputs and f32 accumulation across the V grid
axis, giving the compute-bound roofline corner of the engine family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_N = 256
TILE_V = 512


def _ip_kernel(q_ref, d_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        q_ref[...], d_ref[...].T, preferred_element_type=jnp.float32
    )


def ip_count_pallas(
    data_bin: jnp.ndarray,
    query_bin: jnp.ndarray,
    *,
    tile_q: int = TILE_Q,
    tile_n: int = TILE_N,
    tile_v: int = TILE_V,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns f32 [Q, N] (ops.py rounds to int32).  Inputs bf16/f32 {0,1}."""
    qn, v = query_bin.shape
    nn = data_bin.shape[0]
    assert qn % tile_q == 0 and nn % tile_n == 0 and v % tile_v == 0
    grid = (qn // tile_q, nn // tile_n, v // tile_v)
    return pl.pallas_call(
        _ip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, tile_v), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile_n, tile_v), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, nn), jnp.float32),
        interpret=interpret,
    )(query_bin.astype(jnp.bfloat16), data_bin.astype(jnp.bfloat16))
